//! NCHW shape inference over the computation graph.
//!
//! Every node's output shape is derived from its inputs' shapes. This is
//! also (deliberately) the machinery behind the paper's *shape inference*
//! baseline [15]: from these shapes alone one can sum tensor sizes — and
//! underestimate real memory, as the paper reports (≈46.8% MRE).

use super::op::OpKind;
use super::{Graph, NodeId};

/// Output tensor shape of a node. `[n, c, h, w]` for feature maps,
/// `[n, f]` for flattened/linear tensors, `[n, t, d]` for token
/// sequences (`t` tokens of `d` features each).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TensorShape {
    Map {
        n: usize,
        c: usize,
        h: usize,
        w: usize,
    },
    Vec {
        n: usize,
        f: usize,
    },
    Seq {
        n: usize,
        t: usize,
        d: usize,
    },
}

impl TensorShape {
    /// Saturating: shapes come from untrusted specs, and the serving
    /// path must never panic under `overflow-checks`. `analyze`'s
    /// checked accounting (`DA003`) reports the overflow precisely.
    pub fn elements(&self) -> u64 {
        match *self {
            TensorShape::Map { n, c, h, w } => (n as u64)
                .saturating_mul(c as u64)
                .saturating_mul(h as u64)
                .saturating_mul(w as u64),
            TensorShape::Vec { n, f } => (n as u64).saturating_mul(f as u64),
            TensorShape::Seq { n, t, d } => (n as u64)
                .saturating_mul(t as u64)
                .saturating_mul(d as u64),
        }
    }

    /// Bytes at f32.
    pub fn bytes(&self) -> u64 {
        self.elements().saturating_mul(4)
    }

    pub fn channels(&self) -> usize {
        match *self {
            TensorShape::Map { c, .. } => c,
            TensorShape::Vec { f, .. } => f,
            TensorShape::Seq { d, .. } => d,
        }
    }

    pub fn spatial(&self) -> usize {
        match *self {
            TensorShape::Map { h, .. } => h,
            TensorShape::Vec { .. } | TensorShape::Seq { .. } => 1,
        }
    }

    pub fn batch(&self) -> usize {
        match *self {
            TensorShape::Map { n, .. }
            | TensorShape::Vec { n, .. }
            | TensorShape::Seq { n, .. } => n,
        }
    }

    /// View as a token sequence: `Seq` as-is, a feature map as `h·w`
    /// tokens of `c` features (ViT-style patch grid). `Vec` has no
    /// token axis.
    pub fn as_seq(&self) -> Option<(usize, usize, usize)> {
        match *self {
            TensorShape::Seq { n, t, d } => Some((n, t, d)),
            TensorShape::Map { n, c, h, w } => Some((n, h.saturating_mul(w), c)),
            TensorShape::Vec { .. } => None,
        }
    }
}

/// Infer the output shape of every node for a given batch size and input
/// `channels × hw × hw` resolution (overriding the graph's own `Input`
/// attributes, so one graph serves MNIST 28×28 and CIFAR 32×32 alike).
pub fn infer_shapes(
    g: &Graph,
    batch: usize,
    channels: usize,
    hw: usize,
) -> crate::Result<Vec<TensorShape>> {
    let mut shapes: Vec<TensorShape> = Vec::with_capacity(g.nodes.len());
    for id in 0..g.nodes.len() {
        let shape = infer_next(g, &shapes, id, batch, channels, hw)?;
        shapes.push(shape);
    }
    Ok(shapes)
}

/// Infer the output shape of node `id` given the shapes of all earlier
/// nodes — the stepwise form of [`infer_shapes`]. Callers that need to
/// attribute a failure to their own notion of a node (the ingest
/// validator maps node ids back to spec layer ids) drive the loop
/// themselves and wrap the error per step.
pub fn infer_next(
    g: &Graph,
    shapes: &[TensorShape],
    id: NodeId,
    batch: usize,
    channels: usize,
    hw: usize,
) -> crate::Result<TensorShape> {
    infer_one(g, shapes, id, &g.nodes[id].kind, batch, channels, hw)
}

fn infer_one(
    g: &Graph,
    shapes: &[TensorShape],
    id: NodeId,
    kind: &OpKind,
    batch: usize,
    in_channels: usize,
    in_hw: usize,
) -> crate::Result<TensorShape> {
    let node = &g.nodes[id];
    let input = |i: usize| -> crate::Result<&TensorShape> {
        node.inputs
            .get(i)
            .map(|&src| &shapes[src])
            .ok_or_else(|| crate::err!("node {id} missing input {i}"))
    };
    Ok(match kind {
        OpKind::Input { .. } => TensorShape::Map {
            n: batch,
            c: in_channels,
            h: in_hw,
            w: in_hw,
        },
        // Token-id batch. The channels/hw overrides are image-dataset
        // knobs and do not apply here: seq_len comes from the op itself,
        // and each token is a single id (d=1) until embedded.
        OpKind::SeqInput { seq_len, .. } => TensorShape::Seq {
            n: batch,
            t: *seq_len,
            d: 1,
        },
        OpKind::Conv2d(c) => {
            let TensorShape::Map { n, c: ci, h, .. } = *input(0)? else {
                crate::bail!("node {id}: Conv2d over non-map input");
            };
            if ci != c.in_ch {
                crate::bail!(
                    "graph '{}' node {id}: Conv2d expects {} channels, got {ci}",
                    g.name,
                    c.in_ch
                );
            }
            let oh = c.out_hw(h);
            if oh == 0 {
                crate::bail!("node {id}: Conv2d collapses spatial dim (h={h}, k={})", c.kh);
            }
            TensorShape::Map {
                n,
                c: c.out_ch,
                h: oh,
                w: oh,
            }
        }
        OpKind::BatchNorm { channels } => {
            let s = input(0)?.clone();
            if s.channels() != *channels {
                crate::bail!(
                    "graph '{}' node {id}: BatchNorm expects {channels} channels, got {}",
                    g.name,
                    s.channels()
                );
            }
            s
        }
        OpKind::ReLU
        | OpKind::Sigmoid
        | OpKind::GELU
        | OpKind::Dropout { .. }
        | OpKind::Softmax => input(0)?.clone(),
        OpKind::Embedding { dim, .. } => {
            let Some((n, t, d)) = input(0)?.as_seq() else {
                crate::bail!("node {id}: Embedding over non-sequence input");
            };
            if d != 1 {
                crate::bail!(
                    "graph '{}' node {id}: Embedding expects raw token ids (d=1), got d={d}",
                    g.name
                );
            }
            TensorShape::Seq { n, t, d: *dim }
        }
        OpKind::LayerNorm { dim } => {
            // Accepts a sequence, or a feature map viewed as h·w tokens of
            // c features (ViT patch grid) — no explicit reshape op needed.
            let Some((n, t, d)) = input(0)?.as_seq() else {
                crate::bail!("node {id}: LayerNorm over non-sequence input");
            };
            if d != *dim {
                crate::bail!(
                    "graph '{}' node {id}: LayerNorm expects {dim} features, got {d}",
                    g.name
                );
            }
            TensorShape::Seq { n, t, d }
        }
        OpKind::MultiHeadAttention {
            embed_dim, seq_len, ..
        } => {
            let Some((n, t, d)) = input(0)?.as_seq() else {
                crate::bail!("node {id}: MultiHeadAttention over non-sequence input");
            };
            if d != *embed_dim {
                crate::bail!(
                    "graph '{}' node {id}: MultiHeadAttention expects embed_dim {embed_dim}, got {d}",
                    g.name
                );
            }
            if t != *seq_len {
                crate::bail!(
                    "graph '{}' node {id}: MultiHeadAttention expects seq_len {seq_len}, got {t}",
                    g.name
                );
            }
            TensorShape::Seq { n, t, d }
        }
        OpKind::MaxPool(p) | OpKind::AvgPool(p) => {
            let TensorShape::Map { n, c, h, .. } = *input(0)? else {
                crate::bail!("node {id}: pool over non-map input");
            };
            let oh = p.out_hw(h);
            if oh == 0 {
                crate::bail!("node {id}: pool collapses spatial dim (h={h}, k={})", p.kernel);
            }
            TensorShape::Map { n, c, h: oh, w: oh }
        }
        OpKind::GlobalAvgPool => match *input(0)? {
            TensorShape::Map { n, c, .. } => TensorShape::Map { n, c, h: 1, w: 1 },
            // Mean-pool over the token axis — the standard sequence
            // classification head. Lands back in map-land so the usual
            // Flatten+Linear classifier applies unchanged.
            TensorShape::Seq { n, d, .. } => TensorShape::Map { n, c: d, h: 1, w: 1 },
            TensorShape::Vec { .. } => {
                crate::bail!("node {id}: GlobalAvgPool over non-map input")
            }
        },
        OpKind::Flatten => {
            let s = input(0)?;
            TensorShape::Vec {
                n: s.batch(),
                f: (s.elements() / s.batch() as u64) as usize,
            }
        }
        OpKind::Linear {
            in_features,
            out_features,
        } => match *input(0)? {
            TensorShape::Vec { n, f } => {
                if f != *in_features {
                    crate::bail!(
                        "graph '{}' node {id}: Linear expects {in_features} features, got {f}",
                        g.name
                    );
                }
                TensorShape::Vec {
                    n,
                    f: *out_features,
                }
            }
            // Position-wise (feed-forward) application: the same weight
            // matrix applied at every token.
            TensorShape::Seq { n, t, d } => {
                if d != *in_features {
                    crate::bail!(
                        "graph '{}' node {id}: Linear expects {in_features} features, got {d}",
                        g.name
                    );
                }
                TensorShape::Seq {
                    n,
                    t,
                    d: *out_features,
                }
            }
            TensorShape::Map { .. } => {
                crate::bail!("node {id}: Linear over non-vector input (flatten first)")
            }
        },
        OpKind::Add => {
            let first = input(0)?.clone();
            for i in 1..node.inputs.len() {
                if *input(i)? != first {
                    crate::bail!(
                        "graph '{}' node {id}: Add shape mismatch: {:?} vs {:?}",
                        g.name,
                        first,
                        input(i)?
                    );
                }
            }
            first
        }
        OpKind::Mul => {
            // Broadcast multiply: input0 is the feature map, input1 a
            // per-channel gate (SE block): [n,c,1,1] or identical shape.
            let a = input(0)?.clone();
            let b = input(1)?;
            if a.channels() != b.channels() {
                crate::bail!("node {id}: Mul channel mismatch");
            }
            a
        }
        OpKind::Concat => {
            let TensorShape::Map { n, h, w, mut c } = input(0)?.clone() else {
                crate::bail!("node {id}: Concat over non-map input");
            };
            for i in 1..node.inputs.len() {
                let TensorShape::Map {
                    n: n2,
                    c: c2,
                    h: h2,
                    w: w2,
                } = *input(i)?
                else {
                    crate::bail!("node {id}: Concat over non-map input");
                };
                if n2 != n || h2 != h || w2 != w {
                    crate::bail!(
                        "graph '{}' node {id}: Concat spatial mismatch ({h}x{w} vs {h2}x{w2})",
                        g.name
                    );
                }
                c += c2;
            }
            TensorShape::Map { n, c, h, w }
        }
        OpKind::ChannelShuffle { groups } => {
            let s = input(0)?.clone();
            if s.channels() % groups != 0 {
                crate::bail!("node {id}: ChannelShuffle channels not divisible by groups");
            }
            s
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;

    #[test]
    fn conv_pool_linear_chain() {
        let mut g = Graph::new("chain");
        let x = g.add(OpKind::input(3, 32), &[]);
        let c = g.add(OpKind::conv(3, 16, 3, 1, 1), &[x]);
        let p = g.add(OpKind::maxpool(2, 2), &[c]);
        let f = g.add(OpKind::Flatten, &[p]);
        g.add(
            OpKind::Linear {
                in_features: 16 * 16 * 16,
                out_features: 10,
            },
            &[f],
        );
        let shapes = infer_shapes(&g, 8, 3, 32).unwrap();
        assert_eq!(
            shapes[1],
            TensorShape::Map {
                n: 8,
                c: 16,
                h: 32,
                w: 32
            }
        );
        assert_eq!(
            shapes[2],
            TensorShape::Map {
                n: 8,
                c: 16,
                h: 16,
                w: 16
            }
        );
        assert_eq!(shapes[4], TensorShape::Vec { n: 8, f: 10 });
    }

    #[test]
    fn stride_two_halves() {
        let mut g = Graph::new("s2");
        let x = g.add(OpKind::input(3, 224), &[]);
        g.add(OpKind::conv(3, 64, 7, 2, 3), &[x]);
        let shapes = infer_shapes(&g, 1, 3, 224).unwrap();
        assert_eq!(shapes[1].spatial(), 112);
    }

    #[test]
    fn concat_sums_channels() {
        let mut g = Graph::new("cat");
        let x = g.add(OpKind::input(3, 32), &[]);
        let a = g.add(OpKind::conv(3, 8, 1, 1, 0), &[x]);
        let b = g.add(OpKind::conv(3, 24, 1, 1, 0), &[x]);
        let c = g.add(OpKind::Concat, &[a, b]);
        let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
        assert_eq!(shapes[c].channels(), 32);
    }

    #[test]
    fn add_requires_same_shape() {
        let mut g = Graph::new("bad-add");
        let x = g.add(OpKind::input(3, 32), &[]);
        let a = g.add(OpKind::conv(3, 8, 3, 1, 1), &[x]);
        let b = g.add(OpKind::conv(3, 16, 3, 1, 1), &[x]);
        g.add(OpKind::Add, &[a, b]);
        assert!(infer_shapes(&g, 1, 3, 32).is_err());
    }

    #[test]
    fn channel_mismatch_detected() {
        let mut g = Graph::new("bad-conv");
        let x = g.add(OpKind::input(3, 32), &[]);
        g.add(OpKind::conv(4, 8, 3, 1, 1), &[x]); // expects 4, gets 3
        assert!(infer_shapes(&g, 1, 3, 32).is_err());
    }

    #[test]
    fn linear_feature_mismatch_detected() {
        let mut g = Graph::new("bad-linear");
        let x = g.add(OpKind::input(1, 8), &[]);
        let f = g.add(OpKind::Flatten, &[x]);
        g.add(
            OpKind::Linear {
                in_features: 999,
                out_features: 10,
            },
            &[f],
        );
        assert!(infer_shapes(&g, 1, 1, 8).is_err());
    }

    #[test]
    fn se_mul_broadcast() {
        let mut g = Graph::new("se");
        let x = g.add(OpKind::input(3, 32), &[]);
        let c = g.add(OpKind::conv(3, 8, 3, 1, 1), &[x]);
        let gp = g.add(OpKind::GlobalAvgPool, &[c]);
        let m = g.add(OpKind::Mul, &[c, gp]);
        let shapes = infer_shapes(&g, 4, 3, 32).unwrap();
        assert_eq!(shapes[m], shapes[c]);
    }

    #[test]
    fn bytes_f32() {
        let s = TensorShape::Map {
            n: 2,
            c: 3,
            h: 4,
            w: 4,
        };
        assert_eq!(s.bytes(), 2 * 3 * 4 * 4 * 4);
    }

    #[test]
    fn encoder_block_chain() {
        // SeqInput → Embedding → LayerNorm → MHA → Linear(ffn) → GELU →
        // Linear → GAP → Flatten → Linear classifier.
        let mut g = Graph::new("enc");
        let x = g.add(OpKind::seq_input(64, 1000), &[]);
        let e = g.add(OpKind::Embedding { vocab: 1000, dim: 32 }, &[x]);
        let ln = g.add(OpKind::LayerNorm { dim: 32 }, &[e]);
        let a = g.add(OpKind::mha(32, 4, 64), &[ln]);
        let r = g.add(OpKind::Add, &[a, e]);
        let f1 = g.add(
            OpKind::Linear {
                in_features: 32,
                out_features: 128,
            },
            &[r],
        );
        let ge = g.add(OpKind::GELU, &[f1]);
        let f2 = g.add(
            OpKind::Linear {
                in_features: 128,
                out_features: 32,
            },
            &[ge],
        );
        let gp = g.add(OpKind::GlobalAvgPool, &[f2]);
        let fl = g.add(OpKind::Flatten, &[gp]);
        let head = g.add(
            OpKind::Linear {
                in_features: 32,
                out_features: 2,
            },
            &[fl],
        );
        // The channels/hw overrides are ignored by SeqInput.
        let shapes = infer_shapes(&g, 4, 3, 32).unwrap();
        assert_eq!(shapes[x], TensorShape::Seq { n: 4, t: 64, d: 1 });
        assert_eq!(shapes[e], TensorShape::Seq { n: 4, t: 64, d: 32 });
        assert_eq!(shapes[a], TensorShape::Seq { n: 4, t: 64, d: 32 });
        assert_eq!(shapes[f1], TensorShape::Seq { n: 4, t: 64, d: 128 });
        assert_eq!(
            shapes[gp],
            TensorShape::Map {
                n: 4,
                c: 32,
                h: 1,
                w: 1
            }
        );
        assert_eq!(shapes[head], TensorShape::Vec { n: 4, f: 2 });
    }

    #[test]
    fn map_viewed_as_patch_sequence() {
        // ViT-style: conv patch-embed, then LayerNorm/MHA treat the
        // 8×8 map as 64 tokens of 16 features.
        let mut g = Graph::new("vit");
        let x = g.add(OpKind::input(3, 32), &[]);
        let pe = g.add(OpKind::conv(3, 16, 4, 4, 0), &[x]);
        let ln = g.add(OpKind::LayerNorm { dim: 16 }, &[pe]);
        let a = g.add(OpKind::mha(16, 2, 64), &[ln]);
        let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
        assert_eq!(shapes[ln], TensorShape::Seq { n: 2, t: 64, d: 16 });
        assert_eq!(shapes[a], TensorShape::Seq { n: 2, t: 64, d: 16 });
    }

    #[test]
    fn attn_dim_mismatches_detected() {
        // Wrong embed_dim.
        let mut g = Graph::new("bad-mha-d");
        let x = g.add(OpKind::seq_input(16, 100), &[]);
        let e = g.add(OpKind::Embedding { vocab: 100, dim: 8 }, &[x]);
        g.add(OpKind::mha(32, 4, 16), &[e]);
        assert!(infer_shapes(&g, 1, 3, 32).is_err());
        // Wrong seq_len.
        let mut g2 = Graph::new("bad-mha-t");
        let x = g2.add(OpKind::seq_input(16, 100), &[]);
        let e = g2.add(OpKind::Embedding { vocab: 100, dim: 8 }, &[x]);
        g2.add(OpKind::mha(8, 2, 99), &[e]);
        assert!(infer_shapes(&g2, 1, 3, 32).is_err());
        // Embedding over already-embedded tokens.
        let mut g3 = Graph::new("bad-embed");
        let x = g3.add(OpKind::seq_input(16, 100), &[]);
        let e = g3.add(OpKind::Embedding { vocab: 100, dim: 8 }, &[x]);
        g3.add(OpKind::Embedding { vocab: 100, dim: 8 }, &[e]);
        assert!(infer_shapes(&g3, 1, 3, 32).is_err());
    }
}
