//! The model zoo: every network the paper profiles.
//!
//! 29 "classic" networks (paper §2.1 — used for the 17,300-point
//! dataset and Figures 1–12), 5 "unseen" networks held out for the
//! zero-shot evaluation (Figure 13), the random model generator
//! (5,500 extra points, §3.1), and 4 transformer-era networks
//! ([`transformer`]) exercising the sequence ops end to end.
//!
//! Every zoo graph also round-trips through the [`crate::ingest`] spec
//! format (`export → parse → lower` is the identity), which makes this
//! module the golden corpus for the user-facing model-spec pipeline.

pub mod common;
pub mod densenet;
pub mod googlenet;
pub mod misc;
pub mod mobilenet;
pub mod random;
pub mod resnet;
pub mod shufflenet;
pub mod transformer;
pub mod vgg;

pub use random::{random_net, RandomNetCfg};

use crate::graph::Graph;

/// A model builder: `(input channels, classes) -> Graph`.
pub type Builder = fn(usize, usize) -> Graph;

/// The paper's 29 classic networks (training set).
pub const CLASSIC_29: [(&str, Builder); 29] = [
    ("lenet5", misc::lenet5),
    ("alexnet", misc::alexnet),
    ("vgg11", vgg::vgg11),
    ("vgg13", vgg::vgg13),
    ("vgg16", vgg::vgg16),
    ("vgg19", vgg::vgg19),
    ("googlenet", googlenet::googlenet),
    ("resnet18", resnet::resnet18),
    ("resnet34", resnet::resnet34),
    ("resnet101", resnet::resnet101),
    ("resnet152", resnet::resnet152),
    ("preact-resnet18", resnet::preact_resnet18),
    ("preact-resnet34", resnet::preact_resnet34),
    ("se-resnet18", resnet::se_resnet18),
    ("se-resnet50", resnet::se_resnet50),
    ("stochasticdepth18", resnet::stochastic_depth_resnet18),
    ("wideresnet28-10", resnet::wide_resnet28_10),
    ("resnext29", resnet::resnext29),
    ("mobilenet-v1", mobilenet::mobilenet_v1),
    ("mobilenet-v2", mobilenet::mobilenet_v2),
    ("mnasnet", mobilenet::mnasnet),
    ("efficientnet-b0", mobilenet::efficientnet_b0),
    ("squeezenet", misc::squeezenet),
    ("shufflenet-v1", shufflenet::shufflenet_v1),
    ("shufflenet-v2", shufflenet::shufflenet_v2),
    ("densenet121", densenet::densenet121),
    ("densenet169", densenet::densenet169),
    ("nin", misc::nin),
    ("darknet19", misc::darknet19),
];

/// The 5 unseen networks (Figure 13 zero-shot set). None of these are in
/// [`CLASSIC_29`].
pub const UNSEEN_5: [(&str, Builder); 5] = [
    ("inception-v3", googlenet::inception_v3),
    ("stochasticdepth34", resnet::stochastic_depth_resnet34),
    ("resnet50", resnet::resnet50),
    ("preact-resnet152", resnet::preact_resnet152),
    ("se-resnet34", resnet::se_resnet34),
];

/// The transformer-era family: three text encoders/decoders over a
/// [`crate::graph::OpKind::SeqInput`] root and one ViT-style hybrid
/// over an image root. Kept out of [`CLASSIC_29`]/[`UNSEEN_5`] so the
/// paper's training/zero-shot splits stay byte-identical.
pub const TRANSFORMER_4: [&str; 4] = ["bert-tiny", "bert-mini", "gpt-nano", "vit-lilliput"];

const TRANSFORMER_BUILDERS: [(&str, Builder); 4] = [
    ("bert-tiny", transformer::bert_tiny),
    ("bert-mini", transformer::bert_mini),
    ("gpt-nano", transformer::gpt_nano),
    ("vit-lilliput", transformer::vit_lilliput),
];

/// The models the paper implements in "PyTorch" (18) vs "TensorFlow" (17),
/// 6 shared — mapped onto our TorchSim/TfSim framework policies.
pub fn torch_models() -> Vec<&'static str> {
    CLASSIC_29[..18].iter().map(|(n, _)| *n).collect()
}

pub fn tf_models() -> Vec<&'static str> {
    // Last 17, overlapping the torch set by 6.
    CLASSIC_29[12..].iter().map(|(n, _)| *n).collect()
}

/// Figure 12's five batch-size-generalization models.
pub const FIG12_MODELS: [&str; 5] = [
    "vgg16",
    "se-resnet18",
    "squeezenet",
    "resnet152",
    "shufflenet-v2",
];

/// Look up a builder by name across classic + unseen + transformer sets.
pub fn builder(name: &str) -> Option<Builder> {
    CLASSIC_29
        .iter()
        .chain(UNSEEN_5.iter())
        .chain(TRANSFORMER_BUILDERS.iter())
        .find(|(n, _)| *n == name)
        .map(|(_, b)| *b)
}

/// Build a named model.
pub fn build(name: &str, in_ch: usize, classes: usize) -> crate::Result<Graph> {
    builder(name)
        .map(|b| b(in_ch, classes))
        .ok_or_else(|| crate::err!("unknown model '{name}'"))
}

/// All model names (classic, then unseen, then transformer).
pub fn all_names() -> Vec<&'static str> {
    CLASSIC_29
        .iter()
        .map(|(n, _)| *n)
        .chain(UNSEEN_5.iter().map(|(n, _)| *n))
        .chain(TRANSFORMER_4)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;
    use std::collections::BTreeSet;

    #[test]
    fn exactly_38_models_all_distinct() {
        let names: BTreeSet<&str> = all_names().into_iter().collect();
        assert_eq!(names.len(), 38, "duplicate model names");
    }

    #[test]
    fn unseen_and_transformer_sets_are_disjoint_from_classic() {
        let classic: BTreeSet<&str> = CLASSIC_29.iter().map(|(n, _)| *n).collect();
        for (n, _) in UNSEEN_5 {
            assert!(!classic.contains(n), "{n} leaked into training set");
        }
        for n in TRANSFORMER_4 {
            assert!(!classic.contains(n), "{n} leaked into training set");
            assert!(builder(n).is_some(), "{n} not registered");
        }
    }

    #[test]
    fn every_model_builds_validates_and_infers_cifar_and_mnist() {
        for name in all_names() {
            for (in_ch, classes) in [(3usize, 100usize), (1, 10)] {
                let g = build(name, in_ch, classes).unwrap();
                g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
                let shapes = infer_shapes(&g, 2, in_ch, 32)
                    .unwrap_or_else(|e| panic!("{name}: {e}"));
                assert_eq!(shapes.last().unwrap().channels(), classes, "{name}");
            }
        }
    }

    #[test]
    fn every_model_reports_flops_and_params() {
        for name in all_names() {
            let g = build(name, 3, 100).unwrap();
            assert!(g.param_count() > 0, "{name}");
            assert!(g.flops_per_sample(3, 32).unwrap() > 0, "{name}");
            assert!(g.weighted_layers() >= 2, "{name}");
        }
    }

    #[test]
    fn framework_splits_match_paper_counts() {
        // 18 torch + 17 tf with 6 shared = 29 total.
        let torch: BTreeSet<&str> = torch_models().into_iter().collect();
        let tf: BTreeSet<&str> = tf_models().into_iter().collect();
        assert_eq!(torch.len(), 18);
        assert_eq!(tf.len(), 17);
        assert_eq!(torch.intersection(&tf).count(), 6);
        assert_eq!(torch.union(&tf).count(), 29);
    }

    #[test]
    fn fig12_models_exist() {
        for name in FIG12_MODELS {
            assert!(builder(name).is_some(), "{name}");
        }
    }

    #[test]
    fn unknown_model_errors() {
        assert!(build("transformer-9000", 3, 100).is_err());
    }
}
