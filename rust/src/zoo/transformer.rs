//! Transformer-era networks: tiny BERT-style encoders, a GPT-style
//! decoder stack, and a ViT-style patch-embed hybrid.
//!
//! All four are sized for the profiling envelope (seq_len ≤ 256,
//! embed_dim ≤ 256) rather than for accuracy — what the cost model
//! needs from them is the attention-era *structure*: quadratic-in-t
//! attention, position-wise feed-forward, pre-LN residual topology.
//! Text models take a [`crate::graph::OpKind::SeqInput`] root and
//! ignore the `in_ch` builder argument (token ids have no channels);
//! the ViT hybrid keeps an image root so the conv patch embed adapts
//! to MNIST/CIFAR channel counts like every CNN in the zoo.

use super::common::gap_classifier;
use crate::graph::{Graph, NodeId, OpKind};

/// Pre-LN encoder block (the GPT-2/ViT ordering, which also matches
/// BERT's cost structure): `x + MHA(LN(x))`, then `x + FFN(LN(x))`
/// with a 4× GELU feed-forward.
fn encoder_block(g: &mut Graph, x: NodeId, d: usize, heads: usize, seq: usize) -> NodeId {
    let n1 = g.add(OpKind::LayerNorm { dim: d }, &[x]);
    let attn = g.add(OpKind::mha(d, heads, seq), &[n1]);
    let r1 = g.add(OpKind::Add, &[x, attn]);
    let n2 = g.add(OpKind::LayerNorm { dim: d }, &[r1]);
    let up = g.add(
        OpKind::Linear {
            in_features: d,
            out_features: d * 4,
        },
        &[n2],
    );
    let act = g.add(OpKind::GELU, &[up]);
    let down = g.add(
        OpKind::Linear {
            in_features: d * 4,
            out_features: d,
        },
        &[act],
    );
    g.add(OpKind::Add, &[r1, down])
}

/// Token-classification encoder: embed → blocks → LN → GAP head
/// (mean-pool over tokens, the standard sentence-classification head).
#[allow(clippy::too_many_arguments)]
fn text_encoder(
    name: &str,
    vocab: usize,
    seq: usize,
    d: usize,
    heads: usize,
    depth: usize,
    embed_dropout: bool,
    classes: usize,
) -> Graph {
    let mut g = Graph::new(name);
    let x = g.add(OpKind::seq_input(seq, vocab), &[]);
    let mut cur = g.add(OpKind::Embedding { vocab, dim: d }, &[x]);
    if embed_dropout {
        cur = g.add(OpKind::Dropout { p_keep_x100: 90 }, &[cur]);
    }
    for _ in 0..depth {
        cur = encoder_block(&mut g, cur, d, heads, seq);
    }
    let norm = g.add(OpKind::LayerNorm { dim: d }, &[cur]);
    gap_classifier(&mut g, norm, d, classes);
    g
}

/// BERT-tiny-style encoder: 2 layers, 128 wide, 2 heads, WordPiece
/// vocabulary. `in_ch` is ignored — token ids have no channels.
pub fn bert_tiny(_in_ch: usize, classes: usize) -> Graph {
    text_encoder("bert-tiny", 30_522, 128, 128, 2, 2, false, classes)
}

/// BERT-mini-style encoder: 4 layers, 256 wide, 4 heads.
pub fn bert_mini(_in_ch: usize, classes: usize) -> Graph {
    text_encoder("bert-mini", 30_522, 128, 256, 4, 4, false, classes)
}

/// GPT-style decoder stack: BPE vocabulary, longer context, embedding
/// dropout. Causal masking changes which scores survive the softmax,
/// not how many are computed, so the cost structure is the encoder's.
pub fn gpt_nano(_in_ch: usize, classes: usize) -> Graph {
    text_encoder("gpt-nano", 50_257, 256, 192, 3, 3, true, classes)
}

/// ViT-style hybrid: a 4×4/stride-4 conv patch embed turns the 32×32
/// image into an 8×8 grid, which the first LayerNorm views as 64
/// tokens of 192 features (`TensorShape::as_seq`) — no explicit
/// reshape op needed. Two pre-LN blocks, then the usual GAP head.
pub fn vit_lilliput(in_ch: usize, classes: usize) -> Graph {
    const D: usize = 192;
    let mut g = Graph::new("vit-lilliput");
    let x = g.add(OpKind::input(in_ch, 32), &[]);
    let patches = g.add(OpKind::conv(in_ch, D, 4, 4, 0), &[x]);
    let mut cur = g.add(OpKind::LayerNorm { dim: D }, &[patches]);
    for _ in 0..2 {
        cur = encoder_block(&mut g, cur, D, 3, 64);
    }
    let norm = g.add(OpKind::LayerNorm { dim: D }, &[cur]);
    gap_classifier(&mut g, norm, D, classes);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn text_encoders_ignore_image_geometry() {
        for (name, builder) in [
            ("bert-tiny", bert_tiny as super::super::Builder),
            ("bert-mini", bert_mini),
            ("gpt-nano", gpt_nano),
        ] {
            let g = builder(3, 100);
            g.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            // MNIST and CIFAR overrides must infer identically: the
            // sequence root takes its geometry from the op itself.
            let a = infer_shapes(&g, 2, 3, 32).unwrap();
            let b = infer_shapes(&g, 2, 1, 32).unwrap();
            assert_eq!(a, b, "{name}");
            assert_eq!(a.last().unwrap().channels(), 100, "{name}");
        }
    }

    #[test]
    fn vit_patch_grid_is_64_tokens() {
        let g = vit_lilliput(3, 10);
        let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
        // Node 1 is the patch conv (8×8 map), node 2 the LN im2seq view.
        assert_eq!(shapes[1].spatial(), 8);
        assert!(matches!(
            shapes[2],
            crate::graph::shape::TensorShape::Seq { t: 64, d: 192, .. }
        ));
        assert_eq!(shapes.last().unwrap().channels(), 10);
    }

    #[test]
    fn attention_dominates_bert_flops() {
        // The whole point of threading seq ops through the stack: the
        // featurizer must see attention cost, and attention + FFN must
        // dominate the tiny head.
        let g = bert_tiny(3, 2);
        let total = g.flops_per_sample(3, 32).unwrap();
        let mha: u64 = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.kind, OpKind::MultiHeadAttention { .. }))
            .map(|(id, n)| {
                let shapes = infer_shapes(&g, 1, 3, 32).unwrap();
                crate::graph::flops::node_flops(&g, &shapes, id, &n.kind)
            })
            .sum();
        assert!(mha > 0);
        assert!(mha * 2 > total / 4, "attention must be a visible share");
    }

    #[test]
    fn params_scale_with_depth_and_width() {
        let tiny = bert_tiny(3, 2).param_count();
        let mini = bert_mini(3, 2).param_count();
        assert!(mini > 2 * tiny, "4 layers at 256 wide ≫ 2 layers at 128");
    }
}
