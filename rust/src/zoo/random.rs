//! Random model generator.
//!
//! The paper augments its dataset with 5,500 data points from *randomly
//! generated* deep neural networks (§3.1) so the predictor sees structure
//! beyond the 29 hand-built families. This generator emits valid DAGs in
//! the same operator vocabulary: random stage counts/widths, random block
//! templates (plain conv, residual, inception-ish branch, depthwise
//! separable, SE-gated), random kernel sizes/strides — always
//! shape-correct by construction.

use super::common::{conv_bn, conv_bn_relu, gap_classifier, se_block};
use crate::graph::{Graph, NodeId, OpKind};
use crate::util::prng::Rng;

/// Knobs for the generator (defaults match the dataset sweep).
#[derive(Debug, Clone)]
pub struct RandomNetCfg {
    pub min_stages: usize,
    pub max_stages: usize,
    pub min_blocks_per_stage: usize,
    pub max_blocks_per_stage: usize,
    pub min_width: usize,
    pub max_width: usize,
    pub classes: usize,
    pub in_ch: usize,
}

impl Default for RandomNetCfg {
    fn default() -> Self {
        Self {
            min_stages: 2,
            max_stages: 4,
            min_blocks_per_stage: 1,
            max_blocks_per_stage: 4,
            min_width: 16,
            max_width: 256,
            classes: 100,
            in_ch: 3,
        }
    }
}

/// Generate one random network. Deterministic in (`cfg`, `seed`).
pub fn random_net(cfg: &RandomNetCfg, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut g = Graph::new(&format!("random-{seed:08x}"));
    let x0 = g.add(OpKind::input(cfg.in_ch, 32), &[]);
    let stages = rng.range(cfg.min_stages, cfg.max_stages);
    let mut width = *rng.choose(&[16usize, 24, 32, 48, 64]);
    width = width.clamp(cfg.min_width, cfg.max_width);
    let mut x = conv_bn_relu(&mut g, x0, cfg.in_ch, width, 3, 1, 1);
    let mut ch = width;
    let mut hw = 32usize;
    for stage in 0..stages {
        let blocks = rng.range(cfg.min_blocks_per_stage, cfg.max_blocks_per_stage);
        let target = (width * (1 << stage)).min(cfg.max_width);
        for b in 0..blocks {
            // Downsample at most 3 times so 32×32 never collapses.
            let can_stride = stage > 0 && b == 0 && hw >= 8;
            let stride = if can_stride { 2 } else { 1 };
            if stride == 2 {
                hw /= 2;
            }
            let (nx, nch) = random_block(&mut g, &mut rng, x, ch, target, stride);
            x = nx;
            ch = nch;
        }
    }
    gap_classifier(&mut g, x, ch, cfg.classes);
    g
}

/// One randomly-shaped block. Always returns a valid (node, channels).
fn random_block(
    g: &mut Graph,
    rng: &mut Rng,
    x: NodeId,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
) -> (NodeId, usize) {
    match rng.below(5) {
        // Plain conv stack (1-3 convs, random kernel).
        0 => {
            let depth = rng.range(1, 3);
            let mut cur = x;
            let mut ch = in_ch;
            for d in 0..depth {
                let k = *rng.choose(&[1usize, 3, 5]);
                let s = if d == 0 { stride } else { 1 };
                cur = conv_bn_relu(g, cur, ch, out_ch, k, s, k / 2);
                ch = out_ch;
            }
            (cur, out_ch)
        }
        // Residual basic block.
        1 => {
            let shortcut = if stride != 1 || in_ch != out_ch {
                conv_bn(g, x, in_ch, out_ch, 1, stride, 0)
            } else {
                x
            };
            let h = conv_bn_relu(g, x, in_ch, out_ch, 3, stride, 1);
            let y = conv_bn(g, h, out_ch, out_ch, 3, 1, 1);
            let sum = g.add(OpKind::Add, &[y, shortcut]);
            (g.add(OpKind::ReLU, &[sum]), out_ch)
        }
        // Two-branch inception-ish concat.
        2 => {
            let half = (out_ch / 2).max(1);
            let a = conv_bn_relu(g, x, in_ch, half, 1, stride, 0);
            let r = conv_bn_relu(g, x, in_ch, half, 1, 1, 0);
            let b = conv_bn_relu(g, r, half, out_ch - half, 3, stride, 1);
            let cat = g.add(OpKind::Concat, &[a, b]);
            (cat, out_ch)
        }
        // Depthwise separable.
        3 => {
            let dw = g.add(OpKind::dwconv(in_ch, 3, stride, 1), &[x]);
            let bn = g.add(OpKind::BatchNorm { channels: in_ch }, &[dw]);
            let r = g.add(OpKind::ReLU, &[bn]);
            let pw = conv_bn_relu(g, r, in_ch, out_ch, 1, 1, 0);
            (pw, out_ch)
        }
        // SE-gated conv.
        _ => {
            let k = *rng.choose(&[3usize, 5]);
            let c = conv_bn_relu(g, x, in_ch, out_ch, k, stride, k / 2);
            let s = se_block(g, c, out_ch, 8);
            (s, out_ch)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;
    use crate::util::prop;

    #[test]
    fn deterministic_in_seed() {
        let cfg = RandomNetCfg::default();
        let a = random_net(&cfg, 123);
        let b = random_net(&cfg, 123);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = random_net(&cfg, 124);
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn prop_random_nets_always_valid() {
        let cfg = RandomNetCfg::default();
        prop::check("random-net-valid", 64, move |rng| {
            let g = random_net(&cfg, rng.next_u64());
            g.validate().unwrap();
            let shapes = infer_shapes(&g, 2, cfg.in_ch, 32).unwrap();
            assert_eq!(shapes.last().unwrap().channels(), cfg.classes);
            assert!(g.param_count() > 0);
        });
    }

    #[test]
    fn prop_mnist_config_valid() {
        let cfg = RandomNetCfg {
            in_ch: 1,
            classes: 10,
            ..Default::default()
        };
        prop::check("random-net-mnist", 32, move |rng| {
            let g = random_net(&cfg, rng.next_u64());
            infer_shapes(&g, 4, 1, 32).unwrap();
        });
    }

    #[test]
    fn nets_vary_in_size() {
        let cfg = RandomNetCfg::default();
        let sizes: Vec<u64> = (0..20).map(|s| random_net(&cfg, s).param_count()).collect();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        assert!(max > &(min * 2), "expected diverse sizes, got {sizes:?}");
    }
}
