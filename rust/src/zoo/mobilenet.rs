//! MobileNet-V1/V2, MnasNet and EfficientNet-B0 — the paper's
//! "lightweight" family: dominated by 1×1 pointwise and depthwise
//! convolutions, hence smooth cost curves (only the GEMM algorithm
//! family applies; see paper §2.2 / Figure 1).

use super::common::{conv_bn, conv_bn_relu, dwconv_bn_relu, gap_classifier, se_block};
use crate::graph::{Graph, NodeId, OpKind};

/// MobileNet-V1 (Howard 2017): depthwise-separable stacks.
pub fn mobilenet_v1(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("mobilenet-v1");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 32, 3, 1, 1);
    let mut ch = 32;
    // (out_ch, stride) pairs, CIFAR strides.
    for (out, s) in [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ] {
        x = dwconv_bn_relu(&mut g, x, ch, 3, s);
        x = conv_bn_relu(&mut g, x, ch, out, 1, 1, 0);
        ch = out;
    }
    gap_classifier(&mut g, x, ch, classes);
    g
}

/// MobileNet-V2 inverted residual block.
fn inverted_residual(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
    expand: usize,
    with_se: bool,
) -> (NodeId, usize) {
    let mid = in_ch * expand;
    let mut h = if expand != 1 {
        conv_bn_relu(g, x, in_ch, mid, 1, 1, 0)
    } else {
        x
    };
    h = dwconv_bn_relu(g, h, mid, 3, stride);
    if with_se {
        h = se_block(g, h, mid, 4);
    }
    let y = conv_bn(g, h, mid, out_ch, 1, 1, 0); // linear bottleneck
    let out = if stride == 1 && in_ch == out_ch {
        g.add(OpKind::Add, &[y, x])
    } else {
        y
    };
    (out, out_ch)
}

/// MobileNet-V2 (Sandler 2018), CIFAR adaptation.
pub fn mobilenet_v2(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("mobilenet-v2");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 32, 3, 1, 1);
    let mut ch = 32;
    // (expansion, out_ch, repeats, stride)
    for (t, c, n, s) in [
        (1, 16, 1, 1),
        (6, 24, 2, 1),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ] {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let (nx, nch) = inverted_residual(&mut g, x, ch, c, stride, t, false);
            x = nx;
            ch = nch;
        }
    }
    x = conv_bn_relu(&mut g, x, ch, 1280, 1, 1, 0);
    gap_classifier(&mut g, x, 1280, classes);
    g
}

/// MnasNet-B1-ish (Tan 2019): inverted residuals with mixed expansion.
pub fn mnasnet(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("mnasnet");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 32, 3, 1, 1);
    let mut ch = 32;
    for (t, c, n, s, se) in [
        (1, 16, 1, 1, false),
        (3, 24, 3, 2, false),
        (3, 40, 3, 2, true),
        (6, 80, 3, 2, false),
        (6, 96, 2, 1, true),
        (6, 192, 4, 2, true),
        (6, 320, 1, 1, false),
    ] {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let (nx, nch) = inverted_residual(&mut g, x, ch, c, stride, t, se);
            x = nx;
            ch = nch;
        }
    }
    x = conv_bn_relu(&mut g, x, ch, 1280, 1, 1, 0);
    gap_classifier(&mut g, x, 1280, classes);
    g
}

/// EfficientNet-B0 (Tan & Le 2019), CIFAR adaptation: MBConv + SE blocks.
pub fn efficientnet_b0(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("efficientnet-b0");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 32, 3, 1, 1);
    let mut ch = 32;
    for (t, c, n, s) in [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 40, 2, 2),
        (6, 80, 3, 2),
        (6, 112, 3, 1),
        (6, 192, 4, 2),
        (6, 320, 1, 1),
    ] {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let (nx, nch) = inverted_residual(&mut g, x, ch, c, stride, t, true);
            x = nx;
            ch = nch;
        }
    }
    x = conv_bn_relu(&mut g, x, ch, 1280, 1, 1, 0);
    gap_classifier(&mut g, x, 1280, classes);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{infer_shapes, ConvAttrs};

    fn pointwise_fraction(g: &Graph) -> f64 {
        let convs: Vec<&ConvAttrs> = g
            .nodes
            .iter()
            .filter_map(|n| match &n.kind {
                OpKind::Conv2d(c) => Some(c),
                _ => None,
            })
            .collect();
        let pw = convs.iter().filter(|c| c.is_pointwise()).count();
        pw as f64 / convs.len() as f64
    }

    #[test]
    fn all_validate() {
        for g in [
            mobilenet_v1(3, 100),
            mobilenet_v2(3, 100),
            mnasnet(3, 100),
            efficientnet_b0(3, 100),
        ] {
            g.validate().unwrap();
            let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
            assert_eq!(shapes.last().unwrap().channels(), 100, "{}", g.name);
        }
    }

    #[test]
    fn lightweight_nets_are_pointwise_dominated() {
        // The paper's observation: these nets use "a large number of 1×1
        // convolutional kernels".
        assert!(pointwise_fraction(&mobilenet_v1(3, 100)) > 0.45);
        assert!(pointwise_fraction(&mobilenet_v2(3, 100)) > 0.5);
        assert!(pointwise_fraction(&efficientnet_b0(3, 100)) > 0.5);
    }

    #[test]
    fn v2_residuals_present() {
        let g = mobilenet_v2(3, 100);
        let adds = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Add))
            .count();
        assert!(adds >= 8, "adds={adds}");
    }

    #[test]
    fn efficientnet_has_se_gates() {
        let g = efficientnet_b0(3, 100);
        let muls = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Mul))
            .count();
        assert_eq!(muls, 16); // one per MBConv block
    }
}
