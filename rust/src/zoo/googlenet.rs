//! GoogLeNet / Inception-V1 (Szegedy 2015) and a CIFAR-adapted
//! Inception-V3 (the paper's unseen model), built from Inception modules:
//! parallel 1×1 / 3×3 / 5×5 / pool branches concatenated on channels.

use super::common::{conv_bn_relu, gap_classifier};
use crate::graph::{Graph, NodeId, OpKind};

/// Inception-V1 module: four branches concatenated.
#[allow(clippy::too_many_arguments)]
fn inception_v1(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    b1: usize,       // 1×1
    b3r: usize,      // 3×3 reduce
    b3: usize,       // 3×3
    b5r: usize,      // 5×5 reduce
    b5: usize,       // 5×5 (as two 3×3s, per the BN-inception refinement)
    pool_proj: usize,
) -> (NodeId, usize) {
    let br1 = conv_bn_relu(g, x, in_ch, b1, 1, 1, 0);
    let r3 = conv_bn_relu(g, x, in_ch, b3r, 1, 1, 0);
    let br3 = conv_bn_relu(g, r3, b3r, b3, 3, 1, 1);
    let r5 = conv_bn_relu(g, x, in_ch, b5r, 1, 1, 0);
    let m5 = conv_bn_relu(g, r5, b5r, b5, 3, 1, 1);
    let br5 = conv_bn_relu(g, m5, b5, b5, 3, 1, 1);
    let p = g.add(
        OpKind::MaxPool(crate::graph::PoolAttrs {
            kernel: 3,
            stride: 1,
            padding: 1,
        }),
        &[x],
    );
    let brp = conv_bn_relu(g, p, in_ch, pool_proj, 1, 1, 0);
    let cat = g.add(OpKind::Concat, &[br1, br3, br5, brp]);
    (cat, b1 + b3 + b5 + pool_proj)
}

/// GoogLeNet (Inception-V1), CIFAR adaptation per kuangliu/pytorch-cifar.
pub fn googlenet(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("googlenet");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 192, 3, 1, 1);
    let mut ch = 192;
    // 3a, 3b
    let (a, c) = inception_v1(&mut g, x, ch, 64, 96, 128, 16, 32, 32);
    let (b, c2) = inception_v1(&mut g, a, c, 128, 128, 192, 32, 96, 64);
    x = g.add(OpKind::maxpool(3, 2), &[b]);
    ch = c2;
    // 4a..4e
    for cfg in [
        (192, 96, 208, 16, 48, 64),
        (160, 112, 224, 24, 64, 64),
        (128, 128, 256, 24, 64, 64),
        (112, 144, 288, 32, 64, 64),
        (256, 160, 320, 32, 128, 128),
    ] {
        let (nx, nch) = inception_v1(&mut g, x, ch, cfg.0, cfg.1, cfg.2, cfg.3, cfg.4, cfg.5);
        x = nx;
        ch = nch;
    }
    x = g.add(OpKind::maxpool(2, 2), &[x]);
    // 5a, 5b
    for cfg in [(256, 160, 320, 32, 128, 128), (384, 192, 384, 48, 128, 128)] {
        let (nx, nch) = inception_v1(&mut g, x, ch, cfg.0, cfg.1, cfg.2, cfg.3, cfg.4, cfg.5);
        x = nx;
        ch = nch;
    }
    gap_classifier(&mut g, x, ch, classes);
    g
}

/// Inception-V3 module A: 1×1, 5×5(as 3×3 pair), double 3×3, pool-proj.
fn inception_a(g: &mut Graph, x: NodeId, in_ch: usize, pool_ch: usize) -> (NodeId, usize) {
    let b1 = conv_bn_relu(g, x, in_ch, 64, 1, 1, 0);
    let r5 = conv_bn_relu(g, x, in_ch, 48, 1, 1, 0);
    let b5 = conv_bn_relu(g, r5, 48, 64, 3, 1, 1);
    let r3 = conv_bn_relu(g, x, in_ch, 64, 1, 1, 0);
    let m3 = conv_bn_relu(g, r3, 64, 96, 3, 1, 1);
    let b3 = conv_bn_relu(g, m3, 96, 96, 3, 1, 1);
    let p = g.add(
        OpKind::AvgPool(crate::graph::PoolAttrs {
            kernel: 3,
            stride: 1,
            padding: 1,
        }),
        &[x],
    );
    let bp = conv_bn_relu(g, p, in_ch, pool_ch, 1, 1, 0);
    let cat = g.add(OpKind::Concat, &[b1, b5, b3, bp]);
    (cat, 64 + 64 + 96 + pool_ch)
}

/// Inception-V3 reduction module.
fn reduction_a(g: &mut Graph, x: NodeId, in_ch: usize) -> (NodeId, usize) {
    let b3 = conv_bn_relu(g, x, in_ch, 384, 3, 2, 1);
    let r = conv_bn_relu(g, x, in_ch, 64, 1, 1, 0);
    let m = conv_bn_relu(g, r, 64, 96, 3, 1, 1);
    let b33 = conv_bn_relu(g, m, 96, 96, 3, 2, 1);
    let p = g.add(
        OpKind::MaxPool(crate::graph::PoolAttrs {
            kernel: 3,
            stride: 2,
            padding: 1,
        }),
        &[x],
    );
    let cat = g.add(OpKind::Concat, &[b3, b33, p]);
    (cat, 384 + 96 + in_ch)
}

/// Inception-V3 module C-style with factorized 7×7 → two asymmetric convs
/// approximated as 3×3 pairs (kept square: our IR has square kernels, the
/// cost structure — extra conv calls + concat — is preserved).
fn inception_c(g: &mut Graph, x: NodeId, in_ch: usize, mid: usize) -> (NodeId, usize) {
    let b1 = conv_bn_relu(g, x, in_ch, 192, 1, 1, 0);
    let r7 = conv_bn_relu(g, x, in_ch, mid, 1, 1, 0);
    let a7 = conv_bn_relu(g, r7, mid, mid, 3, 1, 1);
    let b7 = conv_bn_relu(g, a7, mid, 192, 3, 1, 1);
    let r77 = conv_bn_relu(g, x, in_ch, mid, 1, 1, 0);
    let c1 = conv_bn_relu(g, r77, mid, mid, 3, 1, 1);
    let c2 = conv_bn_relu(g, c1, mid, mid, 3, 1, 1);
    let c3 = conv_bn_relu(g, c2, mid, mid, 3, 1, 1);
    let b77 = conv_bn_relu(g, c3, mid, 192, 3, 1, 1);
    let p = g.add(
        OpKind::AvgPool(crate::graph::PoolAttrs {
            kernel: 3,
            stride: 1,
            padding: 1,
        }),
        &[x],
    );
    let bp = conv_bn_relu(g, p, in_ch, 192, 1, 1, 0);
    let cat = g.add(OpKind::Concat, &[b1, b7, b77, bp]);
    (cat, 192 * 4)
}

/// Unseen model (Figure 13): Inception-V3, CIFAR adaptation.
pub fn inception_v3(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("inception-v3");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 32, 3, 1, 1);
    x = conv_bn_relu(&mut g, x, 32, 64, 3, 1, 1);
    let mut ch = 64;
    // 3× module A at 32×32.
    for pool_ch in [32usize, 64, 64] {
        let (nx, nch) = inception_a(&mut g, x, ch, pool_ch);
        x = nx;
        ch = nch;
    }
    let (nx, nch) = reduction_a(&mut g, x, ch);
    x = nx;
    ch = nch;
    // 4× module C at 16×16.
    for mid in [128usize, 160, 160, 192] {
        let (nx, nch) = inception_c(&mut g, x, ch, mid);
        x = nx;
        ch = nch;
    }
    let (nx, nch) = reduction_a(&mut g, x, ch);
    x = nx;
    ch = nch;
    // 2× module A at 8×8 as the tail.
    for pool_ch in [64usize, 64] {
        let (nx, nch) = inception_a(&mut g, x, ch, pool_ch);
        x = nx;
        ch = nch;
    }
    gap_classifier(&mut g, x, ch, classes);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn googlenet_validates() {
        let g = googlenet(3, 100);
        g.validate().unwrap();
        let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
        assert_eq!(shapes.last().unwrap().channels(), 100);
    }

    #[test]
    fn googlenet_has_many_branches() {
        let g = googlenet(3, 100);
        let concats = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::Concat))
            .count();
        assert_eq!(concats, 9); // 9 inception modules
    }

    #[test]
    fn inception_v3_validates() {
        let g = inception_v3(3, 100);
        g.validate().unwrap();
        infer_shapes(&g, 2, 3, 32).unwrap();
        assert!(g.param_count() > 5_000_000);
    }

    #[test]
    fn mnist_variant() {
        let g = googlenet(1, 10);
        infer_shapes(&g, 2, 1, 32).unwrap();
    }
}
