//! Shared building blocks for the model zoo.
//!
//! All networks are built in their CIFAR adaptations (3×3 stem, 32×32
//! inputs, global-average-pool classifier) — the paper profiles training
//! on MNIST (zero-padded to 32×32, as LeNet does) and CIFAR-100, where
//! ImageNet stems would collapse the spatial dimensions.

use crate::graph::{Graph, NodeId, OpKind};

/// `Conv → BN → ReLU`, the workhorse block. Returns the ReLU node.
pub fn conv_bn_relu(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    padding: usize,
) -> NodeId {
    let c = g.add(OpKind::conv_nobias(in_ch, out_ch, k, stride, padding), &[x]);
    let b = g.add(OpKind::BatchNorm { channels: out_ch }, &[c]);
    g.add(OpKind::ReLU, &[b])
}

/// `Conv → BN` (no activation — residual trunks). Returns the BN node.
pub fn conv_bn(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    padding: usize,
) -> NodeId {
    let c = g.add(OpKind::conv_nobias(in_ch, out_ch, k, stride, padding), &[x]);
    g.add(OpKind::BatchNorm { channels: out_ch }, &[c])
}

/// Grouped `Conv → BN → ReLU` (ResNeXt / ShuffleNet).
#[allow(clippy::too_many_arguments)]
pub fn gconv_bn_relu(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    padding: usize,
    groups: usize,
) -> NodeId {
    let c = g.add(
        OpKind::conv_grouped(in_ch, out_ch, k, stride, padding, groups),
        &[x],
    );
    let b = g.add(OpKind::BatchNorm { channels: out_ch }, &[c]);
    g.add(OpKind::ReLU, &[b])
}

/// Depthwise `Conv → BN → ReLU`.
pub fn dwconv_bn_relu(g: &mut Graph, x: NodeId, ch: usize, k: usize, stride: usize) -> NodeId {
    let c = g.add(OpKind::dwconv(ch, k, stride, k / 2), &[x]);
    let b = g.add(OpKind::BatchNorm { channels: ch }, &[c]);
    g.add(OpKind::ReLU, &[b])
}

/// Depthwise `Conv → BN` without activation (MobileNet-V2 style).
pub fn dwconv_bn(g: &mut Graph, x: NodeId, ch: usize, k: usize, stride: usize) -> NodeId {
    let c = g.add(OpKind::dwconv(ch, k, stride, k / 2), &[x]);
    g.add(OpKind::BatchNorm { channels: ch }, &[c])
}

/// Squeeze-and-excitation gate applied to `x` (`ch` channels, reduction
/// `r`): GAP → 1×1 conv down → ReLU → 1×1 conv up → Sigmoid → Mul.
pub fn se_block(g: &mut Graph, x: NodeId, ch: usize, r: usize) -> NodeId {
    let squeeze = (ch / r).max(1);
    let gp = g.add(OpKind::GlobalAvgPool, &[x]);
    let d = g.add(OpKind::conv(ch, squeeze, 1, 1, 0), &[gp]);
    let d = g.add(OpKind::ReLU, &[d]);
    let u = g.add(OpKind::conv(squeeze, ch, 1, 1, 0), &[d]);
    let s = g.add(OpKind::Sigmoid, &[u]);
    g.add(OpKind::Mul, &[x, s])
}

/// Global-average-pool classifier head: GAP → Flatten → Linear(ch→classes).
pub fn gap_classifier(g: &mut Graph, x: NodeId, ch: usize, classes: usize) -> NodeId {
    let gp = g.add(OpKind::GlobalAvgPool, &[x]);
    let f = g.add(OpKind::Flatten, &[gp]);
    g.add(
        OpKind::Linear {
            in_features: ch,
            out_features: classes,
        },
        &[f],
    )
}

/// Classifier with hidden fully-connected layers and dropout (VGG/AlexNet).
pub fn fc_classifier(
    g: &mut Graph,
    x: NodeId,
    in_features: usize,
    hidden: &[usize],
    classes: usize,
) -> NodeId {
    let mut cur = g.add(OpKind::Flatten, &[x]);
    let mut feats = in_features;
    for &h in hidden {
        cur = g.add(
            OpKind::Linear {
                in_features: feats,
                out_features: h,
            },
            &[cur],
        );
        cur = g.add(OpKind::ReLU, &[cur]);
        cur = g.add(OpKind::Dropout { p_keep_x100: 50 }, &[cur]);
        feats = h;
    }
    g.add(
        OpKind::Linear {
            in_features: feats,
            out_features: classes,
        },
        &[cur],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn se_block_preserves_shape() {
        let mut g = Graph::new("se");
        let x = g.add(OpKind::input(3, 32), &[]);
        let c = conv_bn_relu(&mut g, x, 3, 16, 3, 1, 1);
        let s = se_block(&mut g, c, 16, 4);
        let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
        assert_eq!(shapes[s], shapes[c]);
        g.validate().unwrap();
    }

    #[test]
    fn gap_classifier_output() {
        let mut g = Graph::new("head");
        let x = g.add(OpKind::input(3, 32), &[]);
        let c = conv_bn_relu(&mut g, x, 3, 64, 3, 1, 1);
        gap_classifier(&mut g, c, 64, 100);
        let shapes = infer_shapes(&g, 4, 3, 32).unwrap();
        assert_eq!(shapes.last().unwrap().channels(), 100);
    }

    #[test]
    fn fc_classifier_hidden_layers() {
        let mut g = Graph::new("fc");
        let x = g.add(OpKind::input(1, 4), &[]);
        fc_classifier(&mut g, x, 16, &[32, 32], 10);
        let shapes = infer_shapes(&g, 2, 1, 4).unwrap();
        assert_eq!(shapes.last().unwrap().channels(), 10);
        g.validate().unwrap();
    }
}
