//! The remaining classic networks: LeNet-5, AlexNet, SqueezeNet, NiN and
//! DarkNet-19.

use super::common::{conv_bn_relu, fc_classifier};
use crate::graph::{Graph, OpKind};

/// LeNet-5 (LeCun 1998) — the smallest model in the zoo; 32×32 inputs
/// exactly as the original (MNIST zero-padded).
pub fn lenet5(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("lenet5");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let c1 = g.add(OpKind::conv(in_ch, 6, 5, 1, 0), &[x0]); // 28
    let r1 = g.add(OpKind::ReLU, &[c1]);
    let p1 = g.add(OpKind::maxpool(2, 2), &[r1]); // 14
    let c2 = g.add(OpKind::conv(6, 16, 5, 1, 0), &[p1]); // 10
    let r2 = g.add(OpKind::ReLU, &[c2]);
    let p2 = g.add(OpKind::maxpool(2, 2), &[r2]); // 5
    fc_classifier(&mut g, p2, 16 * 5 * 5, &[120, 84], classes);
    g
}

/// AlexNet (Krizhevsky 2012), CIFAR adaptation.
pub fn alexnet(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("alexnet");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let c1 = g.add(OpKind::conv(in_ch, 64, 3, 1, 1), &[x0]);
    let r1 = g.add(OpKind::ReLU, &[c1]);
    let p1 = g.add(OpKind::maxpool(2, 2), &[r1]); // 16
    let c2 = g.add(OpKind::conv(64, 192, 3, 1, 1), &[p1]);
    let r2 = g.add(OpKind::ReLU, &[c2]);
    let p2 = g.add(OpKind::maxpool(2, 2), &[r2]); // 8
    let c3 = g.add(OpKind::conv(192, 384, 3, 1, 1), &[p2]);
    let r3 = g.add(OpKind::ReLU, &[c3]);
    let c4 = g.add(OpKind::conv(384, 256, 3, 1, 1), &[r3]);
    let r4 = g.add(OpKind::ReLU, &[c4]);
    let c5 = g.add(OpKind::conv(256, 256, 3, 1, 1), &[r4]);
    let r5 = g.add(OpKind::ReLU, &[c5]);
    let p5 = g.add(OpKind::maxpool(2, 2), &[r5]); // 4
    fc_classifier(&mut g, p5, 256 * 4 * 4, &[4096, 4096], classes);
    g
}

/// SqueezeNet (Iandola 2016): Fire modules (1×1 squeeze, 1×1+3×3 expand).
pub fn squeezenet(in_ch: usize, classes: usize) -> Graph {
    fn fire(
        g: &mut Graph,
        x: crate::graph::NodeId,
        in_ch: usize,
        squeeze: usize,
        expand: usize,
    ) -> (crate::graph::NodeId, usize) {
        let s = g.add(OpKind::conv(in_ch, squeeze, 1, 1, 0), &[x]);
        let sr = g.add(OpKind::ReLU, &[s]);
        let e1 = g.add(OpKind::conv(squeeze, expand, 1, 1, 0), &[sr]);
        let e1r = g.add(OpKind::ReLU, &[e1]);
        let e3 = g.add(OpKind::conv(squeeze, expand, 3, 1, 1), &[sr]);
        let e3r = g.add(OpKind::ReLU, &[e3]);
        let cat = g.add(OpKind::Concat, &[e1r, e3r]);
        (cat, 2 * expand)
    }
    let mut g = Graph::new("squeezenet");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let c = g.add(OpKind::conv(in_ch, 96, 3, 1, 1), &[x0]);
    let mut x = g.add(OpKind::ReLU, &[c]);
    let mut ch = 96;
    x = g.add(OpKind::maxpool(2, 2), &[x]); // 16
    for (s, e) in [(16, 64), (16, 64), (32, 128)] {
        let (nx, nch) = fire(&mut g, x, ch, s, e);
        x = nx;
        ch = nch;
    }
    x = g.add(OpKind::maxpool(2, 2), &[x]); // 8
    for (s, e) in [(32, 128), (48, 192), (48, 192), (64, 256)] {
        let (nx, nch) = fire(&mut g, x, ch, s, e);
        x = nx;
        ch = nch;
    }
    x = g.add(OpKind::maxpool(2, 2), &[x]); // 4
    let (nx, nch) = fire(&mut g, x, ch, 64, 256);
    // Classifier: 1×1 conv to classes then GAP, as in the original.
    let cc = g.add(OpKind::conv(nch, classes, 1, 1, 0), &[nx]);
    let cr = g.add(OpKind::ReLU, &[cc]);
    let gp = g.add(OpKind::GlobalAvgPool, &[cr]);
    g.add(OpKind::Flatten, &[gp]);
    g
}

/// Network-in-Network (Lin 2013): 1×1 "mlpconv" stacks.
pub fn nin(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("nin");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = x0;
    let mut ch = in_ch;
    for (k, c1, c2, c3, pool) in [
        (5usize, 192usize, 160usize, 96usize, true),
        (5, 192, 192, 192, true),
        (3, 192, 192, 0, false), // last mlpconv maps to classes below
    ] {
        x = conv_bn_relu(&mut g, x, ch, c1, k, 1, k / 2);
        x = conv_bn_relu(&mut g, x, c1, c2, 1, 1, 0);
        let c3 = if c3 == 0 { classes } else { c3 };
        x = conv_bn_relu(&mut g, x, c2, c3, 1, 1, 0);
        ch = c3;
        if pool {
            x = g.add(OpKind::maxpool(2, 2), &[x]);
            x = g.add(OpKind::Dropout { p_keep_x100: 50 }, &[x]);
        }
    }
    let gp = g.add(OpKind::GlobalAvgPool, &[x]);
    let f = g.add(OpKind::Flatten, &[gp]);
    g.add(OpKind::Softmax, &[f]);
    g
}

/// DarkNet-19 (Redmon 2016), the YOLOv2 backbone, CIFAR adaptation:
/// alternating 3×3 and 1×1 convolutions.
pub fn darknet19(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("darknet19");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 32, 3, 1, 1);
    let mut ch = 32;
    x = g.add(OpKind::maxpool(2, 2), &[x]); // 16
    x = conv_bn_relu(&mut g, x, ch, 64, 3, 1, 1);
    ch = 64;
    x = g.add(OpKind::maxpool(2, 2), &[x]); // 8
    for (a, b) in [(128usize, 64usize), (256, 128)] {
        x = conv_bn_relu(&mut g, x, ch, a, 3, 1, 1);
        x = conv_bn_relu(&mut g, x, a, b, 1, 1, 0);
        x = conv_bn_relu(&mut g, x, b, a, 3, 1, 1);
        ch = a;
        x = g.add(OpKind::maxpool(2, 2), &[x]);
    }
    // 2×: five-conv groups at 512 / 1024.
    for big in [512usize, 1024] {
        let small = big / 2;
        x = conv_bn_relu(&mut g, x, ch, big, 3, 1, 1);
        x = conv_bn_relu(&mut g, x, big, small, 1, 1, 0);
        x = conv_bn_relu(&mut g, x, small, big, 3, 1, 1);
        x = conv_bn_relu(&mut g, x, big, small, 1, 1, 0);
        x = conv_bn_relu(&mut g, x, small, big, 3, 1, 1);
        ch = big;
    }
    let cc = g.add(OpKind::conv(ch, classes, 1, 1, 0), &[x]);
    let gp = g.add(OpKind::GlobalAvgPool, &[cc]);
    let f = g.add(OpKind::Flatten, &[gp]);
    g.add(OpKind::Softmax, &[f]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn all_validate_and_classify() {
        for (g, want) in [
            (lenet5(1, 10), 10),
            (alexnet(3, 100), 100),
            (squeezenet(3, 100), 100),
            (nin(3, 100), 100),
            (darknet19(3, 100), 100),
        ] {
            g.validate().unwrap();
            let ch = match g.nodes[0].kind {
                OpKind::Input { channels, .. } => channels,
                _ => unreachable!(),
            };
            let shapes = infer_shapes(&g, 2, ch, 32).unwrap();
            assert_eq!(shapes.last().unwrap().channels(), want, "{}", g.name);
        }
    }

    #[test]
    fn lenet_is_tiny() {
        assert!(lenet5(1, 10).param_count() < 100_000);
    }

    #[test]
    fn squeezenet_small_but_alexnet_level_depth() {
        let sq = squeezenet(3, 100);
        let ax = alexnet(3, 100);
        assert!(sq.param_count() < ax.param_count() / 10);
    }

    #[test]
    fn darknet_alternates_kernel_sizes() {
        let g = darknet19(3, 100);
        let has_1x1 = g.nodes.iter().any(|n| match &n.kind {
            OpKind::Conv2d(c) => c.is_pointwise(),
            _ => false,
        });
        let has_3x3 = g.nodes.iter().any(|n| match &n.kind {
            OpKind::Conv2d(c) => c.kh == 3,
            _ => false,
        });
        assert!(has_1x1 && has_3x3);
    }
}
