//! DenseNet (Huang 2017): every layer concatenates all previous feature
//! maps. Structurally the densest Concat user in the zoo — stresses the
//! NSM's Concat row and the allocator (many live tensors).

use super::common::{conv_bn_relu, gap_classifier};
use crate::graph::{Graph, NodeId, OpKind, PoolAttrs};

/// Dense layer: BN→ReLU→1×1 (bottleneck 4k) → BN→ReLU→3×3 (k), output
/// concatenated with the input.
fn dense_layer(g: &mut Graph, x: NodeId, in_ch: usize, growth: usize) -> (NodeId, usize) {
    let b1 = g.add(OpKind::BatchNorm { channels: in_ch }, &[x]);
    let r1 = g.add(OpKind::ReLU, &[b1]);
    let c1 = g.add(OpKind::conv_nobias(in_ch, 4 * growth, 1, 1, 0), &[r1]);
    let b2 = g.add(
        OpKind::BatchNorm {
            channels: 4 * growth,
        },
        &[c1],
    );
    let r2 = g.add(OpKind::ReLU, &[b2]);
    let c2 = g.add(OpKind::conv_nobias(4 * growth, growth, 3, 1, 1), &[r2]);
    let cat = g.add(OpKind::Concat, &[x, c2]);
    (cat, in_ch + growth)
}

/// Transition: 1×1 halving conv + 2×2 avg-pool.
fn transition(g: &mut Graph, x: NodeId, in_ch: usize) -> (NodeId, usize) {
    let out = in_ch / 2;
    let c = conv_bn_relu(g, x, in_ch, out, 1, 1, 0);
    let p = g.add(
        OpKind::AvgPool(PoolAttrs {
            kernel: 2,
            stride: 2,
            padding: 0,
        }),
        &[c],
    );
    (p, out)
}

fn densenet(name: &str, block_cfg: &[usize], growth: usize, in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new(name);
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut ch = 2 * growth;
    let mut x = conv_bn_relu(&mut g, x0, in_ch, ch, 3, 1, 1);
    for (i, &n) in block_cfg.iter().enumerate() {
        for _ in 0..n {
            let (nx, nch) = dense_layer(&mut g, x, ch, growth);
            x = nx;
            ch = nch;
        }
        if i + 1 != block_cfg.len() {
            let (nx, nch) = transition(&mut g, x, ch);
            x = nx;
            ch = nch;
        }
    }
    gap_classifier(&mut g, x, ch, classes);
    g
}

pub fn densenet121(in_ch: usize, classes: usize) -> Graph {
    densenet("densenet121", &[6, 12, 24, 16], 32, in_ch, classes)
}

pub fn densenet169(in_ch: usize, classes: usize) -> Graph {
    densenet("densenet169", &[6, 12, 32, 32], 32, in_ch, classes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn densenets_validate() {
        for g in [densenet121(3, 100), densenet169(3, 100)] {
            g.validate().unwrap();
            let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
            assert_eq!(shapes.last().unwrap().channels(), 100, "{}", g.name);
        }
    }

    #[test]
    fn growth_accumulates_channels() {
        let g = densenet121(3, 100);
        let shapes = infer_shapes(&g, 1, 3, 32).unwrap();
        // Last dense block output: entering channels + 16×32 growth.
        let pre_gap = &shapes[shapes.len() - 4];
        assert!(pre_gap.channels() > 16 * 32);
    }

    #[test]
    fn densenet121_params_plausible() {
        // Torchvision DenseNet-121 ≈ 8.0M.
        let p = densenet121(3, 100).param_count();
        assert!(p > 6_000_000 && p < 10_000_000, "params={p}");
    }

    #[test]
    fn deeper_means_more_params() {
        assert!(densenet169(3, 100).param_count() > densenet121(3, 100).param_count());
    }
}
