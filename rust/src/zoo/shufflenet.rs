//! ShuffleNet-V1 (Zhang 2018) and ShuffleNet-V2 (Ma 2018): grouped 1×1
//! convolutions + channel shuffle. Lightweight family (smooth cost
//! curves, paper Figure 1); ShuffleNet-V2 appears in Figure 12.

use super::common::{conv_bn_relu, gap_classifier};
use crate::graph::{Graph, NodeId, OpKind, PoolAttrs};

/// ShuffleNet-V1 unit with grouped 1×1s and channel shuffle.
fn v1_unit(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
    groups: usize,
) -> (NodeId, usize) {
    // On stride-2 units the residual is an avg-pool concat, so the branch
    // produces out_ch - in_ch channels.
    let branch_out = if stride == 2 { out_ch - in_ch } else { out_ch };
    let mid = out_ch / 4;
    let c1 = g.add(OpKind::conv_grouped(in_ch, mid, 1, 1, 0, groups), &[x]);
    let b1 = g.add(OpKind::BatchNorm { channels: mid }, &[c1]);
    let r1 = g.add(OpKind::ReLU, &[b1]);
    let sh = g.add(OpKind::ChannelShuffle { groups }, &[r1]);
    let dw = g.add(OpKind::dwconv(mid, 3, stride, 1), &[sh]);
    let bdw = g.add(OpKind::BatchNorm { channels: mid }, &[dw]);
    let c2 = g.add(
        OpKind::conv_grouped(mid, branch_out, 1, 1, 0, groups),
        &[bdw],
    );
    let b2 = g.add(
        OpKind::BatchNorm {
            channels: branch_out,
        },
        &[c2],
    );
    if stride == 2 {
        let p = g.add(
            OpKind::AvgPool(PoolAttrs {
                kernel: 3,
                stride: 2,
                padding: 1,
            }),
            &[x],
        );
        let cat = g.add(OpKind::Concat, &[b2, p]);
        let out = g.add(OpKind::ReLU, &[cat]);
        (out, out_ch)
    } else {
        let sum = g.add(OpKind::Add, &[b2, x]);
        let out = g.add(OpKind::ReLU, &[sum]);
        (out, out_ch)
    }
}

/// ShuffleNet-V1 (groups = 2), CIFAR adaptation.
pub fn shufflenet_v1(in_ch: usize, classes: usize) -> Graph {
    let groups = 2;
    let mut g = Graph::new("shufflenet-v1");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 24, 3, 1, 1);
    let mut ch = 24;
    let stage_out = [200usize, 400, 800];
    for (stage, &out) in stage_out.iter().enumerate() {
        let repeats = if stage == 1 { 8 } else { 4 };
        for b in 0..repeats {
            let stride = if b == 0 { 2 } else { 1 };
            let (nx, nch) = v1_unit(&mut g, x, ch, out, stride, groups);
            x = nx;
            ch = nch;
        }
    }
    gap_classifier(&mut g, x, ch, classes);
    g
}

/// ShuffleNet-V2 basic unit. The real block splits channels in half; our
/// IR has no Split op, so the identity half is modeled by a pointwise
/// projection-free pass-through: branch over x then concat with x's
/// projected half — we emulate with a 1×1 conv producing half channels
/// (cost structure equivalent: the V2 paper's point is equal-width 1×1s
/// and no groups).
fn v2_unit(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    out_ch: usize,
    stride: usize,
) -> (NodeId, usize) {
    let half = out_ch / 2;
    if stride == 1 {
        // Branch on half the channels.
        let keep = g.add(OpKind::conv_nobias(in_ch, half, 1, 1, 0), &[x]);
        let c1 = conv_bn_relu(g, x, in_ch, half, 1, 1, 0);
        let dw = g.add(OpKind::dwconv(half, 3, 1, 1), &[c1]);
        let bdw = g.add(OpKind::BatchNorm { channels: half }, &[dw]);
        let c2 = conv_bn_relu(g, bdw, half, half, 1, 1, 0);
        let cat = g.add(OpKind::Concat, &[keep, c2]);
        let sh = g.add(OpKind::ChannelShuffle { groups: 2 }, &[cat]);
        (sh, out_ch)
    } else {
        // Downsampling unit: both branches strided.
        let dwl = g.add(OpKind::dwconv(in_ch, 3, 2, 1), &[x]);
        let bl = g.add(OpKind::BatchNorm { channels: in_ch }, &[dwl]);
        let left = conv_bn_relu(g, bl, in_ch, half, 1, 1, 0);
        let c1 = conv_bn_relu(g, x, in_ch, half, 1, 1, 0);
        let dwr = g.add(OpKind::dwconv(half, 3, 2, 1), &[c1]);
        let br = g.add(OpKind::BatchNorm { channels: half }, &[dwr]);
        let right = conv_bn_relu(g, br, half, half, 1, 1, 0);
        let cat = g.add(OpKind::Concat, &[left, right]);
        let sh = g.add(OpKind::ChannelShuffle { groups: 2 }, &[cat]);
        (sh, out_ch)
    }
}

/// ShuffleNet-V2 1× (Figure 12 model), CIFAR adaptation.
pub fn shufflenet_v2(in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new("shufflenet-v2");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 24, 3, 1, 1);
    let mut ch = 24;
    for (out, repeats) in [(116usize, 4usize), (232, 8), (464, 4)] {
        for b in 0..repeats {
            let stride = if b == 0 { 2 } else { 1 };
            let (nx, nch) = v2_unit(&mut g, x, ch, out, stride);
            x = nx;
            ch = nch;
        }
    }
    x = conv_bn_relu(&mut g, x, ch, 1024, 1, 1, 0);
    gap_classifier(&mut g, x, 1024, classes);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn both_versions_validate() {
        for g in [shufflenet_v1(3, 100), shufflenet_v2(3, 100)] {
            g.validate().unwrap();
            let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
            assert_eq!(shapes.last().unwrap().channels(), 100, "{}", g.name);
        }
    }

    #[test]
    fn channel_shuffle_present() {
        let g = shufflenet_v1(3, 100);
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.kind, OpKind::ChannelShuffle { .. })));
    }

    #[test]
    fn v2_lighter_than_v1_at_same_classes() {
        // V2 1× is a compact net; both should be well under 10M params.
        assert!(shufflenet_v2(3, 100).param_count() < 10_000_000);
        assert!(shufflenet_v1(3, 100).param_count() < 10_000_000);
    }

    #[test]
    fn v2_unit_keeps_spatial_on_stride1() {
        let g = shufflenet_v2(3, 10);
        let shapes = infer_shapes(&g, 1, 3, 32).unwrap();
        // Final feature map before GAP is 4×4 (three stride-2 stages).
        let last_map = shapes.iter().rev().find(|s| s.spatial() > 1).unwrap();
        assert_eq!(last_map.spatial(), 4);
    }
}
