//! The ResNet family and its variants, CIFAR adaptation (3×3 stem,
//! stages at 64/128/256/512 channels, stride-2 stage transitions).
//!
//! One parameterized builder covers the plain (He 2016a), pre-activation
//! (He 2016b), squeeze-and-excitation (Hu 2018) and stochastic-depth
//! (Huang 2016) variants plus Wide-ResNet and ResNeXt — the paper uses
//! all of these across its seen (Figures 8–12) and unseen (Figure 13)
//! model sets.

use super::common::{conv_bn, conv_bn_relu, gap_classifier, gconv_bn_relu, se_block};
use crate::graph::{Graph, NodeId, OpKind};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BlockKind {
    /// Two 3×3 convs (ResNet-18/34).
    Basic,
    /// 1×1 → 3×3 → 1×1 with 4× expansion (ResNet-50/101/152).
    Bottleneck,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct ResNetStyle {
    /// Pre-activation ordering (BN→ReLU→Conv).
    pub preact: bool,
    /// Append an SE gate to every block.
    pub se: bool,
    /// Stochastic depth: structurally identical to plain ResNet here, but
    /// tagged so the simulator can discount expected depth.
    pub stochastic_depth: bool,
    /// Width multiplier ×10 (10 = 1.0; WideResNet-28-10 uses 100).
    pub width_x10: usize,
    /// Grouped 3×3 cardinality (ResNeXt); 1 = plain.
    pub cardinality: usize,
}

impl ResNetStyle {
    fn width(&self) -> f64 {
        if self.width_x10 == 0 {
            1.0
        } else {
            self.width_x10 as f64 / 10.0
        }
    }

    fn groups(&self) -> usize {
        self.cardinality.max(1)
    }
}

/// Build a ResNet. `blocks` holds the per-stage block counts (4 stages for
/// standard depths, 3 for CIFAR WideResNet).
pub fn resnet(
    name: &str,
    kind: BlockKind,
    blocks: &[usize],
    style: ResNetStyle,
    in_ch: usize,
    classes: usize,
) -> Graph {
    let mut g = Graph::new(name);
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let base = [64usize, 128, 256, 512];
    let stem_ch = (64.0 * style.width()).round() as usize;
    let mut x = conv_bn_relu(&mut g, x0, in_ch, stem_ch, 3, 1, 1);
    let mut ch = stem_ch;
    for (stage, &n) in blocks.iter().enumerate() {
        let planes = (base[stage] as f64 * style.width()).round() as usize;
        for b in 0..n {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let (nx, nch) = match kind {
                BlockKind::Basic => basic_block(&mut g, x, ch, planes, stride, &style),
                BlockKind::Bottleneck => bottleneck(&mut g, x, ch, planes, stride, &style),
            };
            x = nx;
            ch = nch;
        }
    }
    gap_classifier(&mut g, x, ch, classes);
    g
}

/// Plain or pre-activation basic block. Returns (output node, channels).
fn basic_block(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    planes: usize,
    stride: usize,
    style: &ResNetStyle,
) -> (NodeId, usize) {
    let out_ch = planes;
    let shortcut = if stride != 1 || in_ch != out_ch {
        conv_bn(g, x, in_ch, out_ch, 1, stride, 0)
    } else {
        x
    };
    let mut y = if style.preact {
        // BN → ReLU → Conv ×2
        let b = g.add(OpKind::BatchNorm { channels: in_ch }, &[x]);
        let r = g.add(OpKind::ReLU, &[b]);
        let c1 = g.add(OpKind::conv_nobias(in_ch, out_ch, 3, stride, 1), &[r]);
        let b2 = g.add(OpKind::BatchNorm { channels: out_ch }, &[c1]);
        let r2 = g.add(OpKind::ReLU, &[b2]);
        g.add(OpKind::conv_nobias(out_ch, out_ch, 3, 1, 1), &[r2])
    } else {
        let h = conv_bn_relu(g, x, in_ch, out_ch, 3, stride, 1);
        conv_bn(g, h, out_ch, out_ch, 3, 1, 1)
    };
    if style.se {
        y = se_block(g, y, out_ch, 16);
    }
    let sum = g.add(OpKind::Add, &[y, shortcut]);
    let out = if style.preact {
        sum
    } else {
        g.add(OpKind::ReLU, &[sum])
    };
    (out, out_ch)
}

/// Bottleneck block (1×1 reduce, 3×3 [grouped], 1×1 expand ×4).
fn bottleneck(
    g: &mut Graph,
    x: NodeId,
    in_ch: usize,
    planes: usize,
    stride: usize,
    style: &ResNetStyle,
) -> (NodeId, usize) {
    let out_ch = planes * 4;
    let groups = style.groups();
    let mid = if groups > 1 { planes * 2 } else { planes }; // ResNeXt widening
    let shortcut = if stride != 1 || in_ch != out_ch {
        conv_bn(g, x, in_ch, out_ch, 1, stride, 0)
    } else {
        x
    };
    let h = conv_bn_relu(g, x, in_ch, mid, 1, 1, 0);
    let h = if groups > 1 {
        gconv_bn_relu(g, h, mid, mid, 3, stride, 1, groups)
    } else {
        conv_bn_relu(g, h, mid, mid, 3, stride, 1)
    };
    let mut y = conv_bn(g, h, mid, out_ch, 1, 1, 0);
    if style.se {
        y = se_block(g, y, out_ch, 16);
    }
    let sum = g.add(OpKind::Add, &[y, shortcut]);
    let out = g.add(OpKind::ReLU, &[sum]);
    (out, out_ch)
}

// ---- Named configurations --------------------------------------------

pub fn resnet18(in_ch: usize, classes: usize) -> Graph {
    resnet(
        "resnet18",
        BlockKind::Basic,
        &[2, 2, 2, 2],
        ResNetStyle::default(),
        in_ch,
        classes,
    )
}
pub fn resnet34(in_ch: usize, classes: usize) -> Graph {
    resnet(
        "resnet34",
        BlockKind::Basic,
        &[3, 4, 6, 3],
        ResNetStyle::default(),
        in_ch,
        classes,
    )
}
pub fn resnet50(in_ch: usize, classes: usize) -> Graph {
    resnet(
        "resnet50",
        BlockKind::Bottleneck,
        &[3, 4, 6, 3],
        ResNetStyle::default(),
        in_ch,
        classes,
    )
}
pub fn resnet101(in_ch: usize, classes: usize) -> Graph {
    resnet(
        "resnet101",
        BlockKind::Bottleneck,
        &[3, 4, 23, 3],
        ResNetStyle::default(),
        in_ch,
        classes,
    )
}
pub fn resnet152(in_ch: usize, classes: usize) -> Graph {
    resnet(
        "resnet152",
        BlockKind::Bottleneck,
        &[3, 8, 36, 3],
        ResNetStyle::default(),
        in_ch,
        classes,
    )
}

pub fn preact_resnet18(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        preact: true,
        ..Default::default()
    };
    resnet(
        "preact-resnet18",
        BlockKind::Basic,
        &[2, 2, 2, 2],
        style,
        in_ch,
        classes,
    )
}
pub fn preact_resnet34(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        preact: true,
        ..Default::default()
    };
    resnet(
        "preact-resnet34",
        BlockKind::Basic,
        &[3, 4, 6, 3],
        style,
        in_ch,
        classes,
    )
}
/// Unseen model (Figure 13): PreActResNet-152.
pub fn preact_resnet152(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        preact: true,
        ..Default::default()
    };
    resnet(
        "preact-resnet152",
        BlockKind::Bottleneck,
        &[3, 8, 36, 3],
        style,
        in_ch,
        classes,
    )
}

pub fn se_resnet18(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        se: true,
        ..Default::default()
    };
    resnet(
        "se-resnet18",
        BlockKind::Basic,
        &[2, 2, 2, 2],
        style,
        in_ch,
        classes,
    )
}
/// Unseen model (Figure 13): SE-ResNet-34.
pub fn se_resnet34(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        se: true,
        ..Default::default()
    };
    resnet(
        "se-resnet34",
        BlockKind::Basic,
        &[3, 4, 6, 3],
        style,
        in_ch,
        classes,
    )
}
pub fn se_resnet50(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        se: true,
        ..Default::default()
    };
    resnet(
        "se-resnet50",
        BlockKind::Bottleneck,
        &[3, 4, 6, 3],
        style,
        in_ch,
        classes,
    )
}

pub fn stochastic_depth_resnet18(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        stochastic_depth: true,
        ..Default::default()
    };
    resnet(
        "stochasticdepth18",
        BlockKind::Basic,
        &[2, 2, 2, 2],
        style,
        in_ch,
        classes,
    )
}
/// Unseen model (Figure 13): StochasticDepth-34.
pub fn stochastic_depth_resnet34(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        stochastic_depth: true,
        ..Default::default()
    };
    resnet(
        "stochasticdepth34",
        BlockKind::Basic,
        &[3, 4, 6, 3],
        style,
        in_ch,
        classes,
    )
}

/// WideResNet-28-10 (Zagoruyko 2016), 3 stages of 4 basic blocks, 10× width.
pub fn wide_resnet28_10(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        preact: true,
        width_x10: 100,
        ..Default::default()
    };
    // CIFAR WRN uses base widths 16/32/64 ×k; approximating with the
    // shared 4-stage builder truncated to 3 stages at width 1.0×10.
    let mut g = Graph::new("wideresnet28-10");
    let x0 = g.add(OpKind::input(in_ch, 32), &[]);
    let widths = [160usize, 320, 640];
    let mut x = conv_bn_relu(&mut g, x0, in_ch, 16, 3, 1, 1);
    let mut ch = 16;
    for (stage, &w) in widths.iter().enumerate() {
        for b in 0..4usize {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let (nx, nch) = basic_block(&mut g, x, ch, w, stride, &style);
            x = nx;
            ch = nch;
        }
    }
    gap_classifier(&mut g, x, ch, classes);
    g
}

/// ResNeXt-29 (8×64d), CIFAR variant.
pub fn resnext29(in_ch: usize, classes: usize) -> Graph {
    let style = ResNetStyle {
        cardinality: 8,
        ..Default::default()
    };
    resnet(
        "resnext29",
        BlockKind::Bottleneck,
        &[3, 3, 3],
        style,
        in_ch,
        classes,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn all_variants_validate_and_infer() {
        let builders: Vec<fn(usize, usize) -> Graph> = vec![
            resnet18,
            resnet34,
            resnet50,
            resnet101,
            resnet152,
            preact_resnet18,
            preact_resnet34,
            preact_resnet152,
            se_resnet18,
            se_resnet34,
            se_resnet50,
            stochastic_depth_resnet18,
            stochastic_depth_resnet34,
            wide_resnet28_10,
            resnext29,
        ];
        for b in builders {
            let g = b(3, 100);
            g.validate().unwrap();
            let shapes = infer_shapes(&g, 2, 3, 32).unwrap();
            assert_eq!(shapes.last().unwrap().channels(), 100, "{}", g.name);
        }
    }

    #[test]
    fn depth_ordering_by_params() {
        let p18 = resnet18(3, 100).param_count();
        let p34 = resnet34(3, 100).param_count();
        let p101 = resnet101(3, 100).param_count();
        let p152 = resnet152(3, 100).param_count();
        assert!(p18 < p34 && p34 < p101 && p101 < p152);
    }

    #[test]
    fn resnet18_param_count_plausible() {
        // Torchvision ResNet-18 ≈ 11.7M (ImageNet head); CIFAR head smaller.
        let p = resnet18(3, 100).param_count();
        assert!(p > 10_000_000 && p < 12_500_000, "params={p}");
    }

    #[test]
    fn se_adds_params_over_plain() {
        assert!(se_resnet18(3, 100).param_count() > resnet18(3, 100).param_count());
    }

    #[test]
    fn preact_has_same_convs_as_plain() {
        let plain = resnet18(3, 100);
        let pre = preact_resnet18(3, 100);
        let count = |g: &Graph| {
            g.nodes
                .iter()
                .filter(|n| matches!(n.kind, OpKind::Conv2d(_)))
                .count()
        };
        assert_eq!(count(&plain), count(&pre));
    }

    #[test]
    fn mnist_single_channel_works() {
        let g = resnet50(1, 10);
        infer_shapes(&g, 4, 1, 32).unwrap();
    }
}
