//! VGG family (Simonyan & Zisserman 2014), CIFAR adaptation: stacked 3×3
//! conv-BN-ReLU stages separated by 2×2 max-pools, FC classifier.
//! These are the paper's canonical "fluctuating" networks — every conv is
//! 3×3, so the simulator's Winograd/FFT selection applies throughout.

use super::common::{conv_bn_relu, fc_classifier};
use crate::graph::{Graph, OpKind};

/// Stage widths; `0` marks a max-pool.
const VGG11: &[usize] = &[64, 0, 128, 0, 256, 256, 0, 512, 512, 0, 512, 512, 0];
const VGG13: &[usize] = &[
    64, 64, 0, 128, 128, 0, 256, 256, 0, 512, 512, 0, 512, 512, 0,
];
const VGG16: &[usize] = &[
    64, 64, 0, 128, 128, 0, 256, 256, 256, 0, 512, 512, 512, 0, 512, 512, 512, 0,
];
const VGG19: &[usize] = &[
    64, 64, 0, 128, 128, 0, 256, 256, 256, 256, 0, 512, 512, 512, 512, 0, 512, 512, 512, 512, 0,
];

fn vgg(name: &str, cfg: &[usize], in_ch: usize, classes: usize) -> Graph {
    let mut g = Graph::new(name);
    let mut x = g.add(OpKind::input(in_ch, 32), &[]);
    let mut ch = in_ch;
    for &c in cfg {
        if c == 0 {
            x = g.add(OpKind::maxpool(2, 2), &[x]);
        } else {
            x = conv_bn_relu(&mut g, x, ch, c, 3, 1, 1);
            ch = c;
        }
    }
    // After 5 pools on 32x32 the map is 512×1×1.
    fc_classifier(&mut g, x, ch, &[4096, 4096], classes);
    g
}

pub fn vgg11(in_ch: usize, classes: usize) -> Graph {
    vgg("vgg11", VGG11, in_ch, classes)
}
pub fn vgg13(in_ch: usize, classes: usize) -> Graph {
    vgg("vgg13", VGG13, in_ch, classes)
}
pub fn vgg16(in_ch: usize, classes: usize) -> Graph {
    vgg("vgg16", VGG16, in_ch, classes)
}
pub fn vgg19(in_ch: usize, classes: usize) -> Graph {
    vgg("vgg19", VGG19, in_ch, classes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn vgg16_has_16_weighted_layers() {
        let g = vgg16(3, 100);
        assert_eq!(g.weighted_layers(), 13 + 3); // 13 conv + 3 fc
        g.validate().unwrap();
    }

    #[test]
    fn vgg_family_shapes_ok() {
        for g in [vgg11(3, 100), vgg13(3, 100), vgg16(1, 10), vgg19(3, 100)] {
            let shapes = infer_shapes(&g, 2, chan(&g), 32).unwrap();
            assert_eq!(shapes.last().unwrap().channels(), out_classes(&g));
        }
    }

    fn chan(g: &Graph) -> usize {
        match g.nodes[0].kind {
            OpKind::Input { channels, .. } => channels,
            _ => unreachable!(),
        }
    }

    fn out_classes(g: &Graph) -> usize {
        match g.nodes.last().unwrap().kind {
            OpKind::Linear { out_features, .. } => out_features,
            _ => unreachable!(),
        }
    }

    #[test]
    fn vgg16_params_order_of_magnitude() {
        // CIFAR VGG-16 w/ 4096 FCs: tens of millions of parameters.
        let p = vgg16(3, 100).param_count();
        assert!(p > 30_000_000 && p < 60_000_000, "params={p}");
    }
}
