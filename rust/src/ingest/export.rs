//! Exporting graphs back to specs — the inverse of lowering.
//!
//! Every zoo network round-trips `Graph → spec → Graph` exactly, which
//! gives the ingest pipeline a 38-network golden corpus: the spec of a
//! zoo model must lower to a graph `==` the builder's, with identical
//! params, FLOPs, feature vectors and cache keys. Image graphs export
//! under the v1 tag byte-for-byte as before; token-sequence graphs
//! (`SeqInput` root) export a sequence input section under the v2 tag.

use super::spec::{InputSpec, LayerSpec, ModelSpec, INPUT_ID};
use crate::graph::{Graph, OpKind};
use crate::util::json::Json;
use crate::zoo;
use std::collections::BTreeMap;

/// Export a graph as a spec. The graph must be a single-input DAG (all
/// zoo and random-generator graphs are); layer `n<i>` is node `i`.
pub fn spec_from_graph(g: &Graph) -> crate::Result<ModelSpec> {
    let Some(first) = g.nodes.first() else {
        crate::bail!("cannot export an empty graph");
    };
    let input = match first.kind {
        OpKind::Input { channels, hw } => InputSpec::image(channels, hw),
        OpKind::SeqInput { seq_len, vocab } => InputSpec::sequence(seq_len, vocab),
        _ => crate::bail!("graph must start with an Input node"),
    };
    let mut layers = Vec::with_capacity(g.len().saturating_sub(1));
    for (id, node) in g.nodes.iter().enumerate().skip(1) {
        if matches!(node.kind, OpKind::Input { .. } | OpKind::SeqInput { .. }) {
            crate::bail!("node {id}: only single-input graphs are expressible as specs");
        }
        let inputs = node
            .inputs
            .iter()
            .map(|&src| {
                if src == 0 {
                    INPUT_ID.to_string()
                } else {
                    format!("n{src}")
                }
            })
            .collect();
        layers.push(LayerSpec {
            id: format!("n{id}"),
            op: op_name(&node.kind).to_string(),
            inputs: Some(inputs),
            attrs: attrs_json(&node.kind),
        });
    }
    Ok(ModelSpec {
        name: g.name.clone(),
        input,
        layers,
    })
}

/// Export a zoo network (classic or unseen) as a spec.
pub fn spec_for_zoo(name: &str, in_ch: usize, classes: usize) -> crate::Result<ModelSpec> {
    spec_from_graph(&zoo::build(name, in_ch, classes)?)
}

/// The spec-format op name of a non-`Input` kind.
fn op_name(kind: &OpKind) -> &'static str {
    match kind {
        OpKind::Input { .. } | OpKind::SeqInput { .. } => {
            unreachable!("Input is the spec's input section, not a layer")
        }
        OpKind::Conv2d(_) => "conv2d",
        OpKind::BatchNorm { .. } => "batchnorm",
        OpKind::ReLU => "relu",
        OpKind::Sigmoid => "sigmoid",
        OpKind::MaxPool(_) => "maxpool",
        OpKind::AvgPool(_) => "avgpool",
        OpKind::GlobalAvgPool => "globalavgpool",
        OpKind::Linear { .. } => "linear",
        OpKind::Add => "add",
        OpKind::Concat => "concat",
        OpKind::Flatten => "flatten",
        OpKind::Dropout { .. } => "dropout",
        OpKind::Softmax => "softmax",
        OpKind::ChannelShuffle { .. } => "channelshuffle",
        OpKind::Mul => "mul",
        OpKind::Embedding { .. } => "embedding",
        OpKind::LayerNorm { .. } => "layernorm",
        OpKind::MultiHeadAttention { .. } => "multiheadattention",
        OpKind::GELU => "gelu",
    }
}

/// Explicit attrs for a kind (defaults spelled out, so exported specs
/// double as format documentation).
fn attrs_json(kind: &OpKind) -> BTreeMap<String, Json> {
    fn num(m: &mut BTreeMap<String, Json>, k: &str, v: usize) {
        m.insert(k.to_string(), Json::Num(v as f64));
    }
    let mut m = BTreeMap::new();
    match kind {
        OpKind::Conv2d(c) => {
            num(&mut m, "in_ch", c.in_ch);
            num(&mut m, "out_ch", c.out_ch);
            if c.kh == c.kw {
                num(&mut m, "kernel", c.kh);
            } else {
                num(&mut m, "kh", c.kh);
                num(&mut m, "kw", c.kw);
            }
            num(&mut m, "stride", c.stride);
            num(&mut m, "padding", c.padding);
            num(&mut m, "groups", c.groups);
            m.insert("bias".to_string(), Json::Bool(c.bias));
        }
        OpKind::BatchNorm { channels } => num(&mut m, "channels", *channels),
        OpKind::MaxPool(p) | OpKind::AvgPool(p) => {
            num(&mut m, "kernel", p.kernel);
            num(&mut m, "stride", p.stride);
            num(&mut m, "padding", p.padding);
        }
        OpKind::Linear {
            in_features,
            out_features,
        } => {
            num(&mut m, "in_features", *in_features);
            num(&mut m, "out_features", *out_features);
        }
        OpKind::Dropout { p_keep_x100 } => {
            m.insert(
                "p_keep".to_string(),
                Json::Num(*p_keep_x100 as f64 / 100.0),
            );
        }
        OpKind::ChannelShuffle { groups } => num(&mut m, "groups", *groups),
        OpKind::Embedding { vocab, dim } => {
            num(&mut m, "vocab", *vocab);
            num(&mut m, "dim", *dim);
        }
        OpKind::LayerNorm { dim } => num(&mut m, "dim", *dim),
        OpKind::MultiHeadAttention {
            embed_dim,
            heads,
            seq_len,
        } => {
            num(&mut m, "embed_dim", *embed_dim);
            num(&mut m, "heads", *heads);
            num(&mut m, "seq_len", *seq_len);
        }
        _ => {}
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{feature_vector, StructureRep};
    use crate::ingest::ModelSpec;
    use crate::sim::{DatasetKind, TrainConfig};

    /// The tentpole's golden-corpus guarantee: every zoo network
    /// (CNN and transformer alike) round-trips export → JSON text →
    /// parse → lower into a graph that is `==` the builder's, with
    /// identical op counts, params, FLOPs, and byte-identical feature
    /// vectors.
    #[test]
    fn all_38_zoo_networks_roundtrip_exactly() {
        let cfg = TrainConfig::paper_default(DatasetKind::Cifar100, 64);
        for name in zoo::all_names() {
            let built = zoo::build(name, 3, 100).unwrap();
            let text = spec_from_graph(&built).unwrap().to_json().to_string();
            let parsed = ModelSpec::parse_str(&text)
                .unwrap_or_else(|e| panic!("{name}: parse: {e:#}"))
                .compile()
                .unwrap_or_else(|e| panic!("{name}: compile: {e:#}"));
            assert_eq!(parsed.graph, built, "{name}: lowered graph differs");
            assert_eq!(parsed.graph.len(), built.len(), "{name}: op count");
            assert_eq!(parsed.graph.param_count(), built.param_count(), "{name}");
            assert_eq!(
                parsed.graph.flops_per_sample(3, 32).unwrap(),
                built.flops_per_sample(3, 32).unwrap(),
                "{name}: FLOPs"
            );
            assert_eq!(parsed.graph.fingerprint(), built.fingerprint(), "{name}");
            let fa = feature_vector(&built, &cfg, StructureRep::Nsm);
            let fb = feature_vector(&parsed.graph, &cfg, StructureRep::Nsm);
            assert!(
                fa.iter().zip(&fb).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{name}: feature vectors must be byte-identical"
            );
        }
    }

    /// Transformer zoo exports must carry the v2 tag (they use v2-only
    /// ops and a sequence input section), and parse back under version
    /// dispatch; image exports keep the v1 tag byte-for-byte.
    #[test]
    fn transformer_exports_declare_v2_and_cnn_exports_stay_v1() {
        for name in zoo::TRANSFORMER_4 {
            let text = spec_for_zoo(name, 3, 100).unwrap().to_json().to_string();
            assert!(
                text.contains(super::super::spec::SPEC_FORMAT_V2),
                "{name}: transformer export must be tagged v2"
            );
            ModelSpec::parse_str(&text).unwrap().compile().unwrap();
        }
        let cnn = spec_for_zoo("resnet18", 3, 100).unwrap().to_json().to_string();
        assert!(
            cnn.contains(super::super::spec::SPEC_FORMAT),
            "image exports must keep the v1 tag"
        );
    }

    #[test]
    fn mnist_variants_roundtrip_too() {
        for name in ["lenet5", "shufflenet-v2", "densenet121"] {
            let built = zoo::build(name, 1, 10).unwrap();
            let parsed = spec_from_graph(&built).unwrap().compile().unwrap();
            assert_eq!(parsed.graph, built, "{name}");
        }
    }

    #[test]
    fn random_generator_graphs_roundtrip() {
        for seed in 0..8u64 {
            let g = zoo::random_net(&zoo::RandomNetCfg::default(), seed);
            let parsed = spec_from_graph(&g).unwrap().compile().unwrap();
            assert_eq!(parsed.graph, g, "{}", g.name);
        }
    }

    #[test]
    fn export_rejects_empty_graph() {
        assert!(spec_from_graph(&Graph::new("empty")).is_err());
    }

    #[test]
    fn exported_spec_names_branches() {
        let spec = spec_for_zoo("googlenet", 3, 100).unwrap();
        let branchy = spec
            .layers
            .iter()
            .any(|l| l.inputs.as_ref().is_some_and(|i| i.len() >= 2));
        assert!(branchy, "googlenet export must contain multi-input layers");
    }
}
