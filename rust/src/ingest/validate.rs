//! Whole-spec validation: id resolution, topology, arity, and the
//! shape-check pass.
//!
//! [`resolve`] turns a [`ModelSpec`]'s symbolic layer references into
//! graph node indices, rejecting duplicate/reserved ids, unknown ops,
//! bad attrs, dangling references, and forward/self references (the
//! definition-order rule makes any cycle show up as one of the latter).
//! [`shape_check`] then runs NCHW shape inference over the lowered
//! graph one node at a time, so a mismatch is reported against the
//! *layer* that caused it, not a bare node index.

use super::spec::{ModelSpec, INPUT_ID};
use crate::graph::op::OpKind;
use crate::graph::{shape, Graph, NodeId};
use crate::util::error::Context;
use std::collections::HashMap;

/// A structurally-valid spec, ready to lower: one `OpKind` per layer
/// plus resolved graph-node inputs (the graph input is node 0, layer
/// `i` becomes node `i + 1`).
pub(super) struct Resolved {
    pub kinds: Vec<OpKind>,
    pub inputs: Vec<Vec<NodeId>>,
}

/// Structural validation. Every error names the offending layer by
/// index and id.
pub(super) fn resolve(spec: &ModelSpec) -> crate::Result<Resolved> {
    let mut by_id: HashMap<&str, usize> = HashMap::with_capacity(spec.layers.len());
    for (idx, l) in spec.layers.iter().enumerate() {
        if l.id == INPUT_ID {
            crate::bail!("layer {idx}: id '{INPUT_ID}' is reserved for the graph input");
        }
        if l.id.is_empty() {
            crate::bail!("layer {idx}: id must be non-empty");
        }
        if let Some(prev) = by_id.insert(l.id.as_str(), idx) {
            crate::bail!(
                "layer {idx}: duplicate id '{}' (already used by layer {prev})",
                l.id
            );
        }
    }
    let mut kinds = Vec::with_capacity(spec.layers.len());
    let mut inputs = Vec::with_capacity(spec.layers.len());
    for (idx, l) in spec.layers.iter().enumerate() {
        let label = || format!("layer {idx} ('{}')", l.id);
        let kind = l.op_kind().with_context(label)?;
        let refs = match &l.inputs {
            // Sequential default: the previous layer's node, which is
            // `idx` itself (node 0 is the graph input).
            None => vec![idx],
            Some(rs) => {
                if rs.is_empty() {
                    crate::bail!(
                        "{}: 'inputs' must not be empty (omit it to chain sequentially)",
                        label()
                    );
                }
                let mut out = Vec::with_capacity(rs.len());
                for r in rs {
                    out.push(resolve_ref(r, idx, &by_id).with_context(label)?);
                }
                out
            }
        };
        let (min, max) = l.arity();
        if refs.len() < min || refs.len() > max {
            let want = if max == usize::MAX {
                format!("at least {min}")
            } else if min == max {
                format!("exactly {min}")
            } else {
                format!("{min}..={max}")
            };
            crate::bail!(
                "{}: op '{}' takes {want} inputs, got {}",
                label(),
                l.op,
                refs.len()
            );
        }
        kinds.push(kind);
        inputs.push(refs);
    }
    Ok(Resolved { kinds, inputs })
}

fn resolve_ref(r: &str, idx: usize, by_id: &HashMap<&str, usize>) -> crate::Result<NodeId> {
    if r == INPUT_ID {
        return Ok(0);
    }
    match by_id.get(r) {
        Some(&j) if j < idx => Ok(j + 1),
        Some(&j) if j == idx => crate::bail!("references itself (cycle)"),
        Some(_) => crate::bail!(
            "references later layer '{r}' — layers form a DAG in definition order (cycle)"
        ),
        None => crate::bail!("references undefined layer '{r}' (dangling branch)"),
    }
}

/// Run shape inference over the lowered graph at batch 1 and the spec's
/// declared input resolution, attributing any failure to its layer.
pub(super) fn shape_check(spec: &ModelSpec, g: &Graph) -> crate::Result<()> {
    let mut shapes = Vec::with_capacity(g.len());
    for id in 0..g.len() {
        let s = shape::infer_next(g, &shapes, id, 1, spec.input.channels, spec.input.hw).map_err(
            |e| match id.checked_sub(1) {
                Some(i) => e.context(format!(
                    "shape check failed at layer {i} ('{}', op {})",
                    spec.layers[i].id, spec.layers[i].op
                )),
                None => e.context("shape check failed at the input node"),
            },
        )?;
        shapes.push(s);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::spec::{InputSpec, LayerSpec};
    use super::*;
    use crate::util::json::Json;
    use crate::util::prop;
    use std::collections::BTreeMap;

    fn layer(id: &str, op: &str, inputs: Option<&[&str]>) -> LayerSpec {
        LayerSpec {
            id: id.to_string(),
            op: op.to_string(),
            inputs: inputs.map(|rs| rs.iter().map(|s| s.to_string()).collect()),
            attrs: BTreeMap::new(),
        }
    }

    fn conv(id: &str, in_ch: usize, out_ch: usize, inputs: Option<&[&str]>) -> LayerSpec {
        let mut l = layer(id, "conv2d", inputs);
        for (k, v) in [
            ("in_ch", in_ch),
            ("out_ch", out_ch),
            ("kernel", 3),
            ("padding", 1),
        ] {
            l.attrs.insert(k.to_string(), Json::Num(v as f64));
        }
        l
    }

    fn spec_of(layers: Vec<LayerSpec>) -> ModelSpec {
        ModelSpec {
            name: "t".into(),
            input: InputSpec::image(3, 32),
            layers,
        }
    }

    #[test]
    fn sequential_default_chains_to_previous() {
        let r = resolve(&spec_of(vec![conv("a", 3, 8, None), layer("r", "relu", None)])).unwrap();
        assert_eq!(r.inputs, vec![vec![0], vec![1]]);
    }

    #[test]
    fn named_branches_resolve() {
        let s = spec_of(vec![
            conv("a", 3, 8, Some(&["input"])),
            conv("b", 3, 8, Some(&["input"])),
            layer("sum", "add", Some(&["a", "b"])),
        ]);
        let r = resolve(&s).unwrap();
        assert_eq!(r.inputs[2], vec![1, 2]);
    }

    #[test]
    fn dangling_forward_self_and_duplicate_rejected() {
        let e = resolve(&spec_of(vec![layer("r", "relu", Some(&["ghost"]))])).unwrap_err();
        assert!(format!("{e:#}").contains("dangling"), "{e:#}");

        let s = spec_of(vec![
            layer("r", "relu", Some(&["late"])),
            layer("late", "relu", None),
        ]);
        let e = resolve(&s).unwrap_err();
        assert!(format!("{e:#}").contains("cycle"), "{e:#}");

        let e = resolve(&spec_of(vec![layer("r", "relu", Some(&["r"]))])).unwrap_err();
        assert!(format!("{e:#}").contains("itself"), "{e:#}");

        let s = spec_of(vec![layer("r", "relu", None), layer("r", "relu", None)]);
        let e = resolve(&s).unwrap_err();
        assert!(format!("{e:#}").contains("duplicate id"), "{e:#}");
    }

    #[test]
    fn arity_enforced() {
        let e = resolve(&spec_of(vec![layer("s", "add", None)])).unwrap_err();
        assert!(format!("{e:#}").contains("at least 2"), "{e:#}");
        let s = spec_of(vec![
            conv("a", 3, 8, None),
            layer("m", "mul", Some(&["a", "a", "a"])),
        ]);
        let e = resolve(&s).unwrap_err();
        assert!(format!("{e:#}").contains("exactly 2"), "{e:#}");
    }

    #[test]
    fn reserved_input_id_rejected() {
        let e = resolve(&spec_of(vec![layer("input", "relu", None)])).unwrap_err();
        assert!(format!("{e:#}").contains("reserved"), "{e:#}");
    }

    #[test]
    fn shape_errors_name_the_layer() {
        // conv expects 4 channels but the input has 3.
        let s = spec_of(vec![conv("bad-conv", 4, 8, None)]);
        let e = s.compile().unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("bad-conv"), "{msg}");
        assert!(msg.contains("channels"), "{msg}");
    }

    /// Random corruption of a valid chain must always be rejected, and
    /// the error must cite a layer.
    #[test]
    fn prop_corrupted_specs_rejected() {
        prop::check("ingest-corruption-rejected", 48, |rng| {
            let depth = rng.range(2, 6);
            let mut layers = vec![conv("c0", 3, 8, None)];
            for i in 1..depth {
                layers.push(conv(&format!("c{i}"), 8, 8, None));
            }
            let victim = rng.below(layers.len());
            match rng.below(4) {
                0 => layers[victim].op = "warp-drive".into(),
                1 => {
                    layers[victim]
                        .attrs
                        .insert("in_ch".into(), Json::Num(17.0));
                }
                2 => layers[victim].inputs = Some(vec!["nowhere".into()]),
                _ => {
                    let fwd = format!("c{}", layers.len() - 1);
                    layers[0].inputs = Some(vec![fwd]);
                }
            }
            let err = spec_of(layers).compile().unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("layer"), "error must cite a layer: {msg}");
        });
    }
}
