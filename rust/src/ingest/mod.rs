//! Model-spec ingestion — the system's front door for *arbitrary*
//! user-defined networks.
//!
//! The paper's headline claim is zero-shot cost prediction for unseen
//! networks (§3, Figure 13), so the serving path cannot stop at the 34
//! zoo names: users bring their own architectures. This subsystem
//! accepts a declarative JSON model spec, validates it with per-layer
//! diagnostics, and lowers it to the exact [`crate::graph::Graph`] IR
//! the zoo builders emit — after which featurization, prediction,
//! caching and scheduling treat it like any other model:
//!
//! * [`spec`] — the `dnnabacus-spec-v1` format: data model, JSON I/O,
//!   per-layer op/attr interpretation;
//! * `validate` (internal) — whole-spec checks: duplicate ids, unknown
//!   ops, bad attrs, dangling/forward references, arity, and a stepwise
//!   shape pass that attributes mismatches to the offending layer;
//! * [`lower`] — spec → graph, plus [`ParsedSpec`] ([`compile`]d specs
//!   ready to serve). Compiling also runs the [`crate::analyze`] static
//!   analyzer: error-severity findings (`DA00x`) fail the compile,
//!   warnings ride on [`ParsedSpec::warnings`] and surface on `predict`
//!   responses;
//! * [`export`] — graph → spec, so every zoo network round-trips and
//!   serves as the format's golden corpus.
//!
//! The checked-in corpus under `examples/specs/` holds novel (non-zoo)
//! architectures exercising the zero-shot path end to end; see
//! `dnnabacus predict-spec` and the `spec_load` example.

pub mod export;
pub mod lower;
pub mod spec;
mod validate;

pub use export::{spec_for_zoo, spec_from_graph};
pub use lower::{compile, compile_str, ParsedSpec};
pub use spec::{InputSpec, LayerSpec, ModelSpec, INPUT_ID, OP_NAMES, SPEC_FORMAT};
