//! The declarative model-spec format: data model and JSON I/O.
//!
//! A spec is a JSON document describing one network as an input
//! declaration plus an ordered list of layers. Order is definition
//! order; a layer may reference any *earlier* layer by id (named
//! branches), or omit `inputs` entirely to chain sequentially. The
//! reserved id `input` names the graph input.
//!
//! ```json
//! {
//!   "format": "dnnabacus-spec-v1",
//!   "name": "tiny-cnn",
//!   "input": {"channels": 3, "hw": 32},
//!   "layers": [
//!     {"id": "c1", "op": "conv2d",
//!      "attrs": {"in_ch": 3, "out_ch": 8, "kernel": 3, "padding": 1}},
//!     {"op": "relu"},
//!     {"op": "globalavgpool"},
//!     {"op": "flatten"},
//!     {"op": "linear", "attrs": {"in_features": 8, "out_features": 10}}
//!   ]
//! }
//! ```
//!
//! Spec **v2** (`"format": "dnnabacus-spec-v2"`) is a strict superset:
//! the `input` section may instead declare a token sequence
//! (`{"seq_len": 128, "vocab": 30522}`) and four transformer-era ops
//! become available (`embedding`, `layernorm`, `multiheadattention`,
//! `gelu`). v1 documents parse exactly as before; using a v2 feature
//! under the v1 tag is an error naming the offending layer.
//!
//! This module is deliberately *syntactic*: it checks JSON-level shape
//! (fields present, right types) and translates per-layer `op`/`attrs`
//! into [`OpKind`] with precise messages, but whole-spec properties
//! (id uniqueness, reference resolution, shape consistency) live in the
//! internal `validate` module behind [`ModelSpec::compile`].

use crate::graph::op::{ConvAttrs, OpKind, PoolAttrs};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// The v1 format tag (field `format`): conv-era ops, image inputs only.
pub const SPEC_FORMAT: &str = "dnnabacus-spec-v1";

/// The v2 format tag: everything v1 accepts, plus sequence inputs
/// (`seq_len`/`vocab`) and the transformer-era ops. v1 documents keep
/// parsing unchanged — the version is dispatched per document.
pub const SPEC_FORMAT_V2: &str = "dnnabacus-spec-v2";

/// The reserved layer id naming the graph input.
pub const INPUT_ID: &str = "input";

/// Layer op names accepted in `op` fields, in NSM vocabulary order
/// (minus `Input`, which is declared by the `input` section, not a
/// layer).
pub const OP_NAMES: [&str; 19] = [
    "conv2d",
    "batchnorm",
    "relu",
    "sigmoid",
    "maxpool",
    "avgpool",
    "globalavgpool",
    "linear",
    "add",
    "concat",
    "flatten",
    "dropout",
    "softmax",
    "channelshuffle",
    "mul",
    "embedding",
    "layernorm",
    "multiheadattention",
    "gelu",
];

/// The ops a v1 document may not use — declaring one demands the
/// [`SPEC_FORMAT_V2`] tag.
pub const V2_ONLY_OPS: [&str; 4] = ["embedding", "layernorm", "multiheadattention", "gelu"];

/// The `input` section: a `channels × hw × hw` image batch, or (spec v2)
/// a `seq_len`-token sequence over a `vocab`-sized vocabulary. Exactly
/// one of the two styles is populated; the other pair is zero.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    pub channels: usize,
    pub hw: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

impl InputSpec {
    pub fn image(channels: usize, hw: usize) -> InputSpec {
        InputSpec {
            channels,
            hw,
            seq_len: 0,
            vocab: 0,
        }
    }

    pub fn sequence(seq_len: usize, vocab: usize) -> InputSpec {
        InputSpec {
            channels: 0,
            hw: 0,
            seq_len,
            vocab,
        }
    }

    /// Is this a token-sequence input (v2 style)?
    pub fn is_sequence(&self) -> bool {
        self.seq_len > 0
    }
}

/// One layer: an op name, optional explicit inputs, optional attrs.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    /// Unique layer id; auto-assigned (`layer<N>`) when omitted.
    pub id: String,
    /// Op name — one of [`OP_NAMES`].
    pub op: String,
    /// Ids of producing layers (or [`INPUT_ID`]). `None` chains to the
    /// previous layer (the graph input for the first layer).
    pub inputs: Option<Vec<String>>,
    /// Op attributes, kept raw; [`LayerSpec::op_kind`] interprets them.
    pub attrs: BTreeMap<String, Json>,
}

/// A parsed (but not yet validated) model spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub input: InputSpec,
    pub layers: Vec<LayerSpec>,
}

impl ModelSpec {
    /// Parse a spec from JSON text. Syntax errors carry line/column;
    /// structural errors name the offending field or layer.
    pub fn parse_str(text: &str) -> crate::Result<ModelSpec> {
        Self::from_json(&Json::parse(text)?)
    }

    /// Build a spec from an already-parsed JSON document.
    pub fn from_json(doc: &Json) -> crate::Result<ModelSpec> {
        let Json::Obj(fields) = doc else {
            crate::bail!("spec document must be a JSON object");
        };
        for key in fields.keys() {
            if !matches!(key.as_str(), "format" | "name" | "input" | "layers") {
                crate::bail!("unknown field '{key}' (expected format/name/input/layers)");
            }
        }
        let format = match doc.get("format") {
            Some(j) => j
                .as_str()
                .ok_or_else(|| crate::err!("'format' must be a string"))?,
            None => crate::bail!("missing 'format' field (expected \"{SPEC_FORMAT}\")"),
        };
        let v2 = match format {
            SPEC_FORMAT => false,
            SPEC_FORMAT_V2 => true,
            _ => crate::bail!(
                "unsupported format '{format}' (this build reads \"{SPEC_FORMAT}\" and \"{SPEC_FORMAT_V2}\")"
            ),
        };
        let name = match doc.get("name") {
            Some(j) => j
                .as_str()
                .ok_or_else(|| crate::err!("'name' must be a string"))?
                .to_string(),
            None => crate::bail!("missing 'name' field"),
        };
        if name.is_empty() {
            crate::bail!("'name' must be non-empty");
        }
        let input = match doc.get("input") {
            Some(j @ Json::Obj(m)) => {
                let seq_style = m.contains_key("seq_len") || m.contains_key("vocab");
                if seq_style && !v2 {
                    crate::bail!(
                        "input section: sequence inputs (seq_len/vocab) require format \"{SPEC_FORMAT_V2}\""
                    );
                }
                if seq_style {
                    for key in m.keys() {
                        if !matches!(key.as_str(), "seq_len" | "vocab") {
                            crate::bail!(
                                "input section: unknown field '{key}' (expected seq_len/vocab)"
                            );
                        }
                    }
                    InputSpec::sequence(
                        positive_usize(j, "seq_len").map_err(|e| e.context("input section"))?,
                        positive_usize(j, "vocab").map_err(|e| e.context("input section"))?,
                    )
                } else {
                    for key in m.keys() {
                        if !matches!(key.as_str(), "channels" | "hw") {
                            crate::bail!(
                                "input section: unknown field '{key}' (expected channels/hw)"
                            );
                        }
                    }
                    InputSpec::image(
                        positive_usize(j, "channels").map_err(|e| e.context("input section"))?,
                        positive_usize(j, "hw").map_err(|e| e.context("input section"))?,
                    )
                }
            }
            Some(_) => crate::bail!("'input' must be an object"),
            None => crate::bail!("missing 'input' section"),
        };
        let layers_json = match doc.get("layers") {
            Some(j) => j
                .as_arr()
                .ok_or_else(|| crate::err!("'layers' must be an array"))?,
            None => crate::bail!("missing 'layers' field"),
        };
        if layers_json.is_empty() {
            crate::bail!("'layers' must contain at least one layer");
        }
        let mut layers = Vec::with_capacity(layers_json.len());
        for (idx, l) in layers_json.iter().enumerate() {
            layers.push(
                LayerSpec::from_json(l, idx).map_err(|e| e.context(format!("layer {idx}")))?,
            );
        }
        if !v2 {
            for l in &layers {
                if V2_ONLY_OPS.contains(&l.op.as_str()) {
                    crate::bail!(
                        "layer '{}': op '{}' requires format \"{SPEC_FORMAT_V2}\"",
                        l.id,
                        l.op
                    );
                }
            }
        }
        Ok(ModelSpec {
            name,
            input,
            layers,
        })
    }

    /// Does this spec need the v2 format tag? True when the input is a
    /// token sequence or any layer uses a v2-only op. Deriving the tag
    /// from content (rather than storing one) keeps v1 documents
    /// round-trip byte-stable.
    pub fn needs_v2(&self) -> bool {
        self.input.is_sequence()
            || self
                .layers
                .iter()
                .any(|l| V2_ONLY_OPS.contains(&l.op.as_str()))
    }

    /// Serialize back to a JSON document (the inverse of
    /// [`ModelSpec::from_json`] — round-trip exact).
    pub fn to_json(&self) -> Json {
        let mut input = Json::obj();
        if self.input.is_sequence() {
            input
                .set("seq_len", self.input.seq_len)
                .set("vocab", self.input.vocab);
        } else {
            input
                .set("channels", self.input.channels)
                .set("hw", self.input.hw);
        }
        let mut doc = Json::obj();
        doc.set("format", if self.needs_v2() { SPEC_FORMAT_V2 } else { SPEC_FORMAT })
            .set("name", self.name.as_str())
            .set("input", input)
            .set(
                "layers",
                Json::Arr(self.layers.iter().map(LayerSpec::to_json).collect()),
            );
        doc
    }

    /// Validate, lower, and shape-check into a servable [`ParsedSpec`].
    ///
    /// Convenience forward to [`super::lower::compile`].
    pub fn compile(&self) -> crate::Result<super::ParsedSpec> {
        super::lower::compile(self)
    }
}

impl LayerSpec {
    fn from_json(l: &Json, idx: usize) -> crate::Result<LayerSpec> {
        let Json::Obj(fields) = l else {
            crate::bail!("must be a JSON object");
        };
        for key in fields.keys() {
            if !matches!(key.as_str(), "id" | "op" | "inputs" | "attrs") {
                crate::bail!("unknown field '{key}' (expected id/op/inputs/attrs)");
            }
        }
        let op = match l.get("op") {
            Some(j) => j
                .as_str()
                .ok_or_else(|| crate::err!("'op' must be a string"))?,
            None => crate::bail!("missing 'op' field"),
        };
        let id = match l.get("id") {
            Some(j) => {
                let id = j
                    .as_str()
                    .ok_or_else(|| crate::err!("'id' must be a string"))?;
                // `layer<N>` is the auto-naming namespace. An explicit
                // id in it is only allowed at its own position (which
                // is what re-serializing an auto-named spec produces);
                // anywhere else it could collide with the auto id of a
                // later anonymous layer.
                if is_auto_id(id) && id != format!("layer{idx}") {
                    crate::bail!(
                        "id '{id}' is reserved for auto-named layers \
                         (this layer would auto-name as 'layer{idx}')"
                    );
                }
                id.to_string()
            }
            None => format!("layer{idx}"),
        };
        let inputs = match l.get("inputs") {
            None => None,
            Some(Json::Arr(refs)) => {
                let mut out = Vec::with_capacity(refs.len());
                for r in refs {
                    let Some(id) = r.as_str() else {
                        crate::bail!("'inputs' entries must be layer-id strings");
                    };
                    out.push(id.to_string());
                }
                Some(out)
            }
            Some(_) => crate::bail!("'inputs' must be an array of layer ids"),
        };
        let attrs = match l.get("attrs") {
            None => BTreeMap::new(),
            Some(Json::Obj(m)) => m.clone(),
            Some(_) => crate::bail!("'attrs' must be an object"),
        };
        Ok(LayerSpec {
            id,
            op: op.to_string(),
            inputs,
            attrs,
        })
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", self.id.as_str()).set("op", self.op.as_str());
        if let Some(inputs) = &self.inputs {
            o.set(
                "inputs",
                Json::Arr(inputs.iter().map(|s| Json::Str(s.clone())).collect()),
            );
        }
        if !self.attrs.is_empty() {
            o.set("attrs", Json::Obj(self.attrs.clone()));
        }
        o
    }

    /// Interpret `op` + `attrs` as an [`OpKind`]. Rejects unknown ops,
    /// unknown attr keys, missing attrs, and out-of-range values.
    pub fn op_kind(&self) -> crate::Result<OpKind> {
        match self.op.as_str() {
            "conv2d" => {
                self.check_attr_keys(&[
                    "in_ch", "out_ch", "kernel", "kh", "kw", "stride", "padding", "groups", "bias",
                ])?;
                let (kh, kw) = match self.attr("kernel")? {
                    Some(k) => {
                        if self.attrs.contains_key("kh") || self.attrs.contains_key("kw") {
                            crate::bail!("give either 'kernel' or 'kh'/'kw', not both");
                        }
                        (nonzero(k, "kernel")?, nonzero(k, "kernel")?)
                    }
                    None => (
                        nonzero(self.require("kh")?, "kh")?,
                        nonzero(self.require("kw")?, "kw")?,
                    ),
                };
                let in_ch = nonzero(self.require("in_ch")?, "in_ch")?;
                let out_ch = nonzero(self.require("out_ch")?, "out_ch")?;
                let groups = nonzero(self.attr("groups")?.unwrap_or(1), "groups")?;
                if in_ch % groups != 0 || out_ch % groups != 0 {
                    crate::bail!("groups {groups} must divide in_ch {in_ch} and out_ch {out_ch}");
                }
                Ok(OpKind::Conv2d(ConvAttrs {
                    in_ch,
                    out_ch,
                    kh,
                    kw,
                    stride: nonzero(self.attr("stride")?.unwrap_or(1), "stride")?,
                    padding: self.attr("padding")?.unwrap_or(0),
                    groups,
                    bias: self.bool_attr("bias")?.unwrap_or(true),
                }))
            }
            "batchnorm" => {
                self.check_attr_keys(&["channels"])?;
                Ok(OpKind::BatchNorm {
                    channels: nonzero(self.require("channels")?, "channels")?,
                })
            }
            "relu" => self.no_attrs(OpKind::ReLU),
            "sigmoid" => self.no_attrs(OpKind::Sigmoid),
            "maxpool" | "avgpool" => {
                self.check_attr_keys(&["kernel", "stride", "padding"])?;
                let kernel = nonzero(self.require("kernel")?, "kernel")?;
                let attrs = PoolAttrs {
                    kernel,
                    stride: nonzero(self.attr("stride")?.unwrap_or(kernel), "stride")?,
                    padding: self.attr("padding")?.unwrap_or(0),
                };
                Ok(if self.op == "maxpool" {
                    OpKind::MaxPool(attrs)
                } else {
                    OpKind::AvgPool(attrs)
                })
            }
            "globalavgpool" => self.no_attrs(OpKind::GlobalAvgPool),
            "linear" => {
                self.check_attr_keys(&["in_features", "out_features"])?;
                Ok(OpKind::Linear {
                    in_features: nonzero(self.require("in_features")?, "in_features")?,
                    out_features: nonzero(self.require("out_features")?, "out_features")?,
                })
            }
            "add" => self.no_attrs(OpKind::Add),
            "concat" => self.no_attrs(OpKind::Concat),
            "flatten" => self.no_attrs(OpKind::Flatten),
            "dropout" => {
                self.check_attr_keys(&["p_keep"])?;
                let p = match self.attrs.get("p_keep") {
                    None => 0.5,
                    Some(j) => j
                        .as_f64()
                        .ok_or_else(|| crate::err!("'p_keep' must be a number"))?,
                };
                if !(p > 0.0 && p <= 1.0) {
                    crate::bail!("'p_keep' must be in (0, 1], got {p}");
                }
                Ok(OpKind::Dropout {
                    p_keep_x100: (p * 100.0).round() as usize,
                })
            }
            "softmax" => self.no_attrs(OpKind::Softmax),
            "channelshuffle" => {
                self.check_attr_keys(&["groups"])?;
                Ok(OpKind::ChannelShuffle {
                    groups: nonzero(self.require("groups")?, "groups")?,
                })
            }
            "mul" => self.no_attrs(OpKind::Mul),
            "embedding" => {
                self.check_attr_keys(&["vocab", "dim"])?;
                Ok(OpKind::Embedding {
                    vocab: nonzero(self.require("vocab")?, "vocab")?,
                    dim: nonzero(self.require("dim")?, "dim")?,
                })
            }
            "layernorm" => {
                self.check_attr_keys(&["dim"])?;
                Ok(OpKind::LayerNorm {
                    dim: nonzero(self.require("dim")?, "dim")?,
                })
            }
            // heads dividing embed_dim is *not* checked here: that is the
            // analyzer's DA034, which reports it with a diagnostic rather
            // than a parse failure.
            "multiheadattention" => {
                self.check_attr_keys(&["embed_dim", "heads", "seq_len"])?;
                Ok(OpKind::MultiHeadAttention {
                    embed_dim: nonzero(self.require("embed_dim")?, "embed_dim")?,
                    heads: nonzero(self.require("heads")?, "heads")?,
                    seq_len: nonzero(self.require("seq_len")?, "seq_len")?,
                })
            }
            "gelu" => self.no_attrs(OpKind::GELU),
            other => crate::bail!("unknown op '{other}' (known ops: {})", OP_NAMES.join(", ")),
        }
    }

    /// How many inputs this op consumes: `(min, max)`, `max == usize::MAX`
    /// for variadic ops.
    pub fn arity(&self) -> (usize, usize) {
        match self.op.as_str() {
            "add" | "concat" => (2, usize::MAX),
            "mul" => (2, 2),
            _ => (1, 1),
        }
    }

    fn check_attr_keys(&self, allowed: &[&str]) -> crate::Result<()> {
        for key in self.attrs.keys() {
            if !allowed.contains(&key.as_str()) {
                crate::bail!(
                    "op '{}' has no attr '{key}' (allowed: {})",
                    self.op,
                    allowed.join(", ")
                );
            }
        }
        Ok(())
    }

    fn no_attrs(&self, kind: OpKind) -> crate::Result<OpKind> {
        if let Some(key) = self.attrs.keys().next() {
            crate::bail!("op '{}' takes no attrs, got '{key}'", self.op);
        }
        Ok(kind)
    }

    /// An optional non-negative-integer attr.
    fn attr(&self, key: &str) -> crate::Result<Option<usize>> {
        match self.attrs.get(key) {
            None => Ok(None),
            Some(j) => Ok(Some(as_count(j).map_err(|e| e.context(format!("attr '{key}'")))?)),
        }
    }

    /// A required non-negative-integer attr.
    fn require(&self, key: &str) -> crate::Result<usize> {
        self.attr(key)?
            .ok_or_else(|| crate::err!("op '{}' requires attr '{key}'", self.op))
    }

    fn bool_attr(&self, key: &str) -> crate::Result<Option<bool>> {
        match self.attrs.get(key) {
            None => Ok(None),
            Some(Json::Bool(b)) => Ok(Some(*b)),
            Some(_) => crate::bail!("attr '{key}' must be a boolean"),
        }
    }
}

/// Does `id` fall in the `layer<N>` auto-naming namespace?
fn is_auto_id(id: &str) -> bool {
    id.strip_prefix("layer")
        .is_some_and(|rest| !rest.is_empty() && rest.bytes().all(|b| b.is_ascii_digit()))
}

/// A JSON number used as a count: finite, non-negative, integral.
fn as_count(j: &Json) -> crate::Result<usize> {
    let x = j.as_f64().ok_or_else(|| crate::err!("must be a number"))?;
    if !(x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x < 1e15) {
        crate::bail!("must be a non-negative integer, got {x}");
    }
    Ok(x as usize)
}

fn nonzero(x: usize, what: &str) -> crate::Result<usize> {
    if x == 0 {
        crate::bail!("'{what}' must be >= 1");
    }
    Ok(x)
}

/// `get(key)` as a count that must be `>= 1`.
fn positive_usize(obj: &Json, key: &str) -> crate::Result<usize> {
    let j = obj
        .get(key)
        .ok_or_else(|| crate::err!("missing '{key}'"))?;
    nonzero(as_count(j).map_err(|e| e.context(format!("'{key}'")))?, key)
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"{
        "format": "dnnabacus-spec-v1",
        "name": "tiny",
        "input": {"channels": 3, "hw": 32},
        "layers": [
            {"id": "c1", "op": "conv2d",
             "attrs": {"in_ch": 3, "out_ch": 8, "kernel": 3, "padding": 1}},
            {"op": "relu"},
            {"op": "globalavgpool"},
            {"op": "flatten"},
            {"op": "linear", "attrs": {"in_features": 8, "out_features": 10}}
        ]
    }"#;

    #[test]
    fn parses_tiny_spec() {
        let s = ModelSpec::parse_str(TINY).unwrap();
        assert_eq!(s.name, "tiny");
        assert_eq!(s.input, InputSpec::image(3, 32));
        assert_eq!(s.layers.len(), 5);
        assert_eq!(s.layers[0].id, "c1");
        assert_eq!(s.layers[1].id, "layer1", "auto id");
        assert!(s.layers[0].inputs.is_none(), "sequential default");
    }

    #[test]
    fn json_roundtrip_exact() {
        let s = ModelSpec::parse_str(TINY).unwrap();
        let back = ModelSpec::from_json(&s.to_json()).unwrap();
        // Auto ids become explicit on re-serialize, so compare one more hop.
        assert_eq!(back, ModelSpec::from_json(&back.to_json()).unwrap());
        assert_eq!(back.layers.len(), s.layers.len());
    }

    #[test]
    fn rejects_missing_or_wrong_format() {
        assert!(ModelSpec::parse_str("{}").is_err());
        let e = ModelSpec::parse_str(r#"{"format": "v0", "name": "x"}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("unsupported format"), "{e}");
    }

    #[test]
    fn rejects_unknown_op_with_vocabulary() {
        let l = LayerSpec {
            id: "x".into(),
            op: "transformer".into(),
            inputs: None,
            attrs: BTreeMap::new(),
        };
        let e = l.op_kind().unwrap_err().to_string();
        assert!(e.contains("unknown op 'transformer'"), "{e}");
        assert!(e.contains("conv2d"), "{e}");
    }

    #[test]
    fn rejects_unknown_and_missing_attrs() {
        let mut attrs = BTreeMap::new();
        attrs.insert("in_ch".to_string(), Json::Num(3.0));
        let l = LayerSpec {
            id: "c".into(),
            op: "conv2d".into(),
            inputs: None,
            attrs: attrs.clone(),
        };
        assert!(l.op_kind().unwrap_err().to_string().contains("requires attr"));
        attrs.insert("paddding".to_string(), Json::Num(1.0));
        let l = LayerSpec { attrs, ..l };
        let e = l.op_kind().unwrap_err().to_string();
        assert!(e.contains("no attr 'paddding'"), "{e}");
    }

    #[test]
    fn explicit_ids_cannot_squat_the_auto_namespace() {
        // "layer1" at index 0 would collide with the auto id of the
        // anonymous layer at index 1; the parser rejects it up front.
        let e = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v1", "name": "x",
                "input": {"channels": 3, "hw": 32},
                "layers": [{"id": "layer1", "op": "relu"}, {"op": "relu"}]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("reserved for auto-named"), "{e:#}");
        // At its own position the auto-form id is fine — that is what
        // re-serializing an auto-named spec produces.
        let s = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v1", "name": "x",
                "input": {"channels": 3, "hw": 32},
                "layers": [{"op": "relu"}, {"id": "layer1", "op": "relu"}]}"#,
        )
        .unwrap();
        assert_eq!(s.layers[1].id, "layer1");
        // Non-numeric suffixes are ordinary ids.
        assert!(!is_auto_id("layers"));
        assert!(!is_auto_id("layer"));
        assert!(!is_auto_id("layer1a"));
        assert!(is_auto_id("layer0"));
        assert!(is_auto_id("layer42"));
    }

    #[test]
    fn wrong_type_fields_are_not_reported_as_missing() {
        let e = ModelSpec::parse_str(r#"{"format": 7}"#).unwrap_err().to_string();
        assert!(e.contains("'format' must be a string"), "{e}");
        let e = ModelSpec::parse_str(r#"{"format": "dnnabacus-spec-v1", "name": 7}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("'name' must be a string"), "{e}");
        let doc = r#"{"format": "dnnabacus-spec-v1", "name": "x",
                      "input": {"channels": 3, "hw": 32},
                      "layers": [{"op": 3}]}"#;
        let e = format!("{:#}", ModelSpec::parse_str(doc).unwrap_err());
        assert!(e.contains("'op' must be a string"), "{e}");
    }

    #[test]
    fn unknown_top_level_and_input_fields_rejected() {
        let e = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v1", "name": "x", "notes": "hi",
                "input": {"channels": 3, "hw": 32}, "layers": [{"op": "relu"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("unknown field 'notes'"), "{e}");
        // A typo'd knob in the input section must not be silently dropped.
        let e = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v1", "name": "x",
                "input": {"channels": 3, "hw": 32, "batch": 64},
                "layers": [{"op": "relu"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("unknown field 'batch'"), "{e}");
    }

    #[test]
    fn rejects_fractional_counts() {
        let e = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v1", "name": "x",
                "input": {"channels": 2.5, "hw": 32},
                "layers": [{"op": "relu"}]}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("non-negative integer"), "{e:#}");
    }

    const TINY_V2: &str = r#"{
        "format": "dnnabacus-spec-v2",
        "name": "tiny-encoder",
        "input": {"seq_len": 16, "vocab": 100},
        "layers": [
            {"id": "emb", "op": "embedding", "attrs": {"vocab": 100, "dim": 8}},
            {"op": "layernorm", "attrs": {"dim": 8}},
            {"op": "multiheadattention",
             "attrs": {"embed_dim": 8, "heads": 2, "seq_len": 16}},
            {"op": "gelu"},
            {"op": "globalavgpool"},
            {"op": "flatten"},
            {"op": "linear", "attrs": {"in_features": 8, "out_features": 2}}
        ]
    }"#;

    #[test]
    fn parses_v2_sequence_spec() {
        let s = ModelSpec::parse_str(TINY_V2).unwrap();
        assert_eq!(s.input, InputSpec::sequence(16, 100));
        assert!(s.input.is_sequence());
        assert!(s.needs_v2());
        assert_eq!(s.layers[0].op_kind().unwrap(), OpKind::Embedding { vocab: 100, dim: 8 });
        assert_eq!(s.layers[2].op_kind().unwrap(), OpKind::mha(8, 2, 16));
    }

    #[test]
    fn v1_documents_cannot_use_v2_features() {
        // v2-only op under the v1 tag: the error names the layer and op.
        let e = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v1", "name": "x",
                "input": {"channels": 3, "hw": 32},
                "layers": [{"op": "relu"}, {"id": "ln", "op": "layernorm",
                            "attrs": {"dim": 3}}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("layer 'ln'") && e.contains("dnnabacus-spec-v2"), "{e}");
        // Sequence input under the v1 tag.
        let e = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v1", "name": "x",
                "input": {"seq_len": 16, "vocab": 100},
                "layers": [{"op": "gelu"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("require format"), "{e}");
    }

    #[test]
    fn format_tag_is_derived_from_content() {
        // A v1 document round-trips with the v1 tag (byte-stable corpus)…
        let v1 = ModelSpec::parse_str(TINY).unwrap();
        assert!(!v1.needs_v2());
        assert_eq!(v1.to_json().get("format").unwrap().as_str(), Some(SPEC_FORMAT));
        // …and a sequence document re-exports as v2 and re-parses equal.
        let v2 = ModelSpec::parse_str(TINY_V2).unwrap();
        assert_eq!(v2.to_json().get("format").unwrap().as_str(), Some(SPEC_FORMAT_V2));
        let back = ModelSpec::from_json(&v2.to_json()).unwrap();
        assert_eq!(back, ModelSpec::from_json(&back.to_json()).unwrap());
        // A v2-tagged document using only v1 features normalizes to v1.
        let plain = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v2", "name": "x",
                "input": {"channels": 3, "hw": 32},
                "layers": [{"op": "relu"}]}"#,
        )
        .unwrap();
        assert_eq!(plain.to_json().get("format").unwrap().as_str(), Some(SPEC_FORMAT));
    }

    #[test]
    fn mixed_input_styles_rejected() {
        let e = ModelSpec::parse_str(
            r#"{"format": "dnnabacus-spec-v2", "name": "x",
                "input": {"seq_len": 16, "vocab": 100, "hw": 32},
                "layers": [{"op": "gelu"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("unknown field 'hw'"), "{e}");
    }

    #[test]
    fn op_names_cover_every_non_input_op_type() {
        use crate::graph::op::OpType;
        assert_eq!(OP_NAMES.len(), OpType::ALL.len() - 1);
        for l in OP_NAMES {
            let layer = LayerSpec {
                id: "x".into(),
                op: l.into(),
                inputs: None,
                attrs: BTreeMap::new(),
            };
            // Every name resolves (possibly demanding attrs, never "unknown op").
            if let Err(e) = layer.op_kind() {
                assert!(!e.to_string().contains("unknown op"), "{l}: {e}");
            }
        }
    }
}
