//! Lowering: a validated spec becomes the same [`Graph`] IR the zoo
//! builders emit.
//!
//! Layer order in the spec *is* node order in the graph (after the
//! implicit `Input` node), so a spec exported from a zoo network lowers
//! back to a graph that is `==` the builder's — which is what makes the
//! feature vectors, fingerprints, and cache keys of spec and zoo twins
//! identical.

use super::spec::{InputSpec, ModelSpec};
use super::validate;
use crate::graph::{Graph, OpKind};
use crate::sim::DatasetKind;

/// A compiled spec: validated, lowered, shape-checked, statically
/// analyzed — ready to featurize and serve.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedSpec {
    pub name: String,
    pub input: InputSpec,
    pub graph: Graph,
    /// Non-fatal findings from [`crate::analyze`] (warn/info severity,
    /// attributed to spec layer ids), computed once at compile time.
    /// Error-severity findings never land here — they fail [`compile`].
    /// Serving forwards these on `predict` responses.
    pub warnings: Vec<crate::analyze::Diagnostic>,
}

impl ParsedSpec {
    /// Channels the network's input expects (requests must bring a
    /// dataset with this channel count).
    pub fn input_channels(&self) -> usize {
        self.input.channels
    }

    /// The spec's declared input resolution.
    pub fn input_hw(&self) -> usize {
        self.input.hw
    }

    /// The dataset this spec's input matches, if any. Token-sequence
    /// specs match the sequence corpus directly — they never go through
    /// the channels×hw image-geometry check.
    pub fn matching_dataset(&self) -> Option<DatasetKind> {
        if self.input.is_sequence() {
            return Some(DatasetKind::Sst2);
        }
        DatasetKind::for_channels(self.input.channels)
            .filter(|d| d.hw() == self.input.hw)
    }

    /// Error unless this spec's declared input matches `dataset`'s
    /// sample geometry — the single compatibility gate every consumer
    /// (featurize, predict-spec, serve) goes through. The spec was
    /// shape-checked at its *declared* geometry, so featurizing at a
    /// different one would silently describe a network that does not
    /// exist.
    pub fn check_dataset(&self, dataset: DatasetKind) -> crate::Result<()> {
        if self.input.is_sequence() {
            if !dataset.is_sequence() {
                crate::bail!(
                    "spec '{}' declares a {}-token sequence input but dataset {} provides \
                     {}-channel {}x{} image samples",
                    self.name,
                    self.input.seq_len,
                    dataset.name(),
                    dataset.in_channels(),
                    dataset.hw(),
                    dataset.hw()
                );
            }
            return Ok(());
        }
        if dataset.is_sequence() {
            crate::bail!(
                "spec '{}' declares a {}-channel {}x{} image input but dataset {} provides \
                 token-sequence samples",
                self.name,
                self.input.channels,
                self.input.hw,
                self.input.hw,
                dataset.name()
            );
        }
        if self.input.channels != dataset.in_channels() || self.input.hw != dataset.hw() {
            crate::bail!(
                "spec '{}' declares a {}-channel {}x{} input but dataset {} provides \
                 {}-channel {}x{} samples",
                self.name,
                self.input.channels,
                self.input.hw,
                self.input.hw,
                dataset.name(),
                dataset.in_channels(),
                dataset.hw(),
                dataset.hw()
            );
        }
        Ok(())
    }
}

/// The one-call front door: parse JSON text, validate, lower,
/// shape-check. What `predict-spec`, `serve --specs`, and the load
/// generators all go through.
pub fn compile_str(text: &str) -> crate::Result<ParsedSpec> {
    compile(&ModelSpec::parse_str(text)?)
}

/// Validate + lower + shape-check + statically analyze a spec into a
/// [`ParsedSpec`]. Analyzer errors (overflowing accounting, `DA00x`)
/// fail the compile — the cost model would only produce garbage for
/// such a network; warnings travel on [`ParsedSpec::warnings`].
pub fn compile(spec: &ModelSpec) -> crate::Result<ParsedSpec> {
    let graph = lower(spec)?;
    validate::shape_check(spec, &graph)?;
    let opts = crate::analyze::Options::for_input(spec.input.channels, spec.input.hw);
    let mut report = crate::analyze::run_graph(&graph, &opts);
    report.attribute(spec);
    if let Some(d) = report.first_error() {
        crate::bail!("spec '{}' rejected by static analysis: {}", spec.name, d.render());
    }
    Ok(ParsedSpec {
        name: spec.name.clone(),
        input: spec.input.clone(),
        graph,
        warnings: report.diagnostics,
    })
}

/// Structurally validate and lower a spec to a [`Graph`] (no shape
/// check — [`compile`] is the full front door).
pub fn lower(spec: &ModelSpec) -> crate::Result<Graph> {
    let resolved = validate::resolve(spec)?;
    let mut g = Graph::new(&spec.name);
    if spec.input.is_sequence() {
        g.add(OpKind::seq_input(spec.input.seq_len, spec.input.vocab), &[]);
    } else {
        g.add(OpKind::input(spec.input.channels, spec.input.hw), &[]);
    }
    for (kind, inputs) in resolved.kinds.into_iter().zip(&resolved.inputs) {
        g.add(kind, inputs);
    }
    debug_assert!(g.validate().is_ok());
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{feature_vector, StructureRep};
    use crate::sim::{DatasetKind, TrainConfig};

    const BRANCHY: &str = r#"{
        "format": "dnnabacus-spec-v1",
        "name": "branchy",
        "input": {"channels": 3, "hw": 32},
        "layers": [
            {"id": "a", "op": "conv2d", "inputs": ["input"],
             "attrs": {"in_ch": 3, "out_ch": 8, "kernel": 1}},
            {"id": "b", "op": "conv2d", "inputs": ["input"],
             "attrs": {"in_ch": 3, "out_ch": 24, "kernel": 1}},
            {"id": "cat", "op": "concat", "inputs": ["a", "b"]},
            {"op": "globalavgpool"},
            {"op": "flatten"},
            {"op": "linear", "attrs": {"in_features": 32, "out_features": 10}}
        ]
    }"#;

    #[test]
    fn lowers_branchy_spec_to_valid_graph() {
        let spec = crate::ingest::ModelSpec::parse_str(BRANCHY).unwrap();
        let parsed = spec.compile().unwrap();
        let g = &parsed.graph;
        g.validate().unwrap();
        assert_eq!(g.len(), 7, "input + 6 layers");
        assert_eq!(g.nodes[3].inputs, vec![1, 2], "concat of both branches");
        assert!(g.flops_per_sample(3, 32).unwrap() > 0);
    }

    #[test]
    fn compiled_spec_is_featurizable() {
        let parsed = crate::ingest::ModelSpec::parse_str(BRANCHY)
            .unwrap()
            .compile()
            .unwrap();
        let cfg = TrainConfig::paper_default(DatasetKind::Cifar100, 32);
        let f = feature_vector(&parsed.graph, &cfg, StructureRep::Nsm);
        assert_eq!(f.len(), crate::features::feature_dim(StructureRep::Nsm));
        assert!(f.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn dataset_gate_matches_declared_geometry() {
        let parsed = crate::ingest::ModelSpec::parse_str(BRANCHY)
            .unwrap()
            .compile()
            .unwrap();
        assert_eq!(parsed.matching_dataset(), Some(DatasetKind::Cifar100));
        parsed.check_dataset(DatasetKind::Cifar100).unwrap();
        let e = parsed.check_dataset(DatasetKind::Mnist).unwrap_err();
        assert!(e.to_string().contains("channel"), "{e}");
        // A 64x64 input matches no dataset even with 3 channels.
        let mut hw64 = parsed.clone();
        hw64.input.hw = 64;
        assert_eq!(hw64.matching_dataset(), None);
        assert!(hw64.check_dataset(DatasetKind::Cifar100).is_err());
    }

    const SEQ_SPEC: &str = r#"{
        "format": "dnnabacus-spec-v2",
        "name": "seq-tiny",
        "input": {"seq_len": 16, "vocab": 100},
        "layers": [
            {"op": "embedding", "attrs": {"vocab": 100, "dim": 8}},
            {"op": "layernorm", "attrs": {"dim": 8}},
            {"op": "multiheadattention",
             "attrs": {"embed_dim": 8, "heads": 2, "seq_len": 16}},
            {"op": "globalavgpool"},
            {"op": "flatten"},
            {"op": "linear", "attrs": {"in_features": 8, "out_features": 2}}
        ]
    }"#;

    #[test]
    fn sequence_spec_compiles_and_matches_sequence_dataset() {
        let parsed = compile_str(SEQ_SPEC).unwrap();
        assert!(matches!(
            parsed.graph.nodes[0].kind,
            crate::graph::OpKind::SeqInput { seq_len: 16, vocab: 100 }
        ));
        // The sequence path never consults channel geometry.
        assert_eq!(parsed.matching_dataset(), Some(DatasetKind::Sst2));
        parsed.check_dataset(DatasetKind::Sst2).unwrap();
        let e = parsed.check_dataset(DatasetKind::Mnist).unwrap_err();
        assert!(e.to_string().contains("token sequence"), "{e}");
        // And image specs reject the sequence corpus.
        let img = crate::ingest::ModelSpec::parse_str(BRANCHY)
            .unwrap()
            .compile()
            .unwrap();
        assert!(img.check_dataset(DatasetKind::Sst2).is_err());
        // Featurizable end to end at the matched dataset.
        let cfg = TrainConfig::paper_default(DatasetKind::Sst2, 32);
        let f = feature_vector(&parsed.graph, &cfg, StructureRep::Nsm);
        assert_eq!(f.len(), crate::features::feature_dim(StructureRep::Nsm));
        assert!(f.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn lower_alone_skips_shape_check() {
        // in_ch 4 against a 3-channel input: lower() builds the graph,
        // compile() rejects it.
        let text = r#"{
            "format": "dnnabacus-spec-v1", "name": "x",
            "input": {"channels": 3, "hw": 32},
            "layers": [{"op": "conv2d",
                        "attrs": {"in_ch": 4, "out_ch": 8, "kernel": 3}}]
        }"#;
        let spec = crate::ingest::ModelSpec::parse_str(text).unwrap();
        assert!(lower(&spec).is_ok());
        assert!(spec.compile().is_err());
    }
}
