//! `dnnabacus` — the command-line launcher.
//!
//! ```text
//! dnnabacus <command> [--flags]
//!
//! Experiments (regenerate the paper's tables/figures):
//!   table1 fig1 fig2 fig3 fig4 fig8 fig9 fig10 fig11 fig12 fig13 fig14
//!   headline        overall test MRE (paper: 0.9% time / 2.8% memory)
//!   ablation        structure-independent features vs + NSM
//!   all             every experiment above except fig13/ablation (slow)
//!
//! Pipeline:
//!   collect         run the profiling sweeps, write dataset JSON
//!   train           train AutoML predictors, write model JSON
//!   predict         predict one (model, config) cost
//!   serve           run the prediction service demo (load generator)
//!   nsm-demo        print the NSM of a model (paper Figures 6-7)
//!
//! Common flags: --scale 0.35 --seed 42 --out dir --model vgg16
//!               --batch 128 --dataset cifar100|mnist --device rtx2080
//!               --framework pytorch|tensorflow --backend automl|mlp
//!
//! `serve` flags: --requests 256 --workers 2 --cache-capacity 4096
//!                --cache-ttl-ms 120000   (capacity 0 disables caching)
//!
//! `--backend mlp` needs the AOT artifacts (python/compile/aot.py) and a
//! PJRT binding; this zero-dependency build ships a stub backend, so the
//! default `automl` backend is the serving path.
//! ```

use dnnabacus::coordinator::{
    service::{AutoMlBackend, MlpBackend},
    PredictRequest, PredictionService, ServiceConfig,
};
use dnnabacus::experiments::{self, Ctx};
use dnnabacus::features::Nsm;
use dnnabacus::predictor::{AutoMl, Target};
use dnnabacus::sim::{DatasetKind, DeviceProfile, Framework, Optimizer, TrainConfig};
use dnnabacus::util::cli::Args;
use dnnabacus::util::prng::Rng;
use dnnabacus::zoo;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let result = match args.command.as_deref() {
        Some("all") => run_all(&args),
        Some("collect") => collect(&args),
        Some("train") => train(&args),
        Some("predict") => predict(&args),
        Some("serve") => serve(&args),
        Some("nsm-demo") => nsm_demo(&args),
        Some(cmd) => run_experiment(cmd, &args),
        None => {
            eprintln!("usage: dnnabacus <command> [--flags]; see the README");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from(args: &Args) -> Ctx {
    Ctx {
        scale: args.f64_or("scale", 0.25),
        seed: args.u64_or("seed", 0xDA7A),
        cache_dir: Some(PathBuf::from(
            args.str_or("cache-dir", "target/dnnabacus-cache"),
        )),
    }
}

fn run_experiment(name: &str, args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    for table in experiments::run(name, &ctx)? {
        println!("{}", table.render());
        if args.bool("csv") {
            println!("{}", table.to_csv());
        }
    }
    Ok(())
}

fn run_all(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    for name in experiments::ALL_EXPERIMENTS {
        println!("==== {name} ====");
        for table in experiments::run(name, &ctx)? {
            println!("{}", table.render());
        }
    }
    println!("==== headline ====");
    for table in experiments::run("headline", &ctx)? {
        println!("{}", table.render());
    }
    Ok(())
}

fn collect(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let out = PathBuf::from(args.str_or("out", "target/dnnabacus-data"));
    std::fs::create_dir_all(&out)?;
    let classic = ctx.classic_dataset();
    classic.save(&out.join("classic.json"))?;
    println!(
        "classic sweep: {} points -> {}",
        classic.len(),
        out.join("classic.json").display()
    );
    let random = ctx.random_dataset();
    random.save(&out.join("random.json"))?;
    println!("random sweep: {} points", random.len());
    let unseen = ctx.unseen_dataset();
    unseen.save(&out.join("unseen.json"))?;
    println!("unseen sweep: {} points", unseen.len());
    Ok(())
}

fn train(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let out = PathBuf::from(args.str_or("out", "target/dnnabacus-models"));
    std::fs::create_dir_all(&out)?;
    let corpus = ctx.training_corpus();
    let (train, test) = corpus.split(0.7, ctx.seed);
    for target in [Target::Time, Target::Memory] {
        let m = AutoMl::train_opt(&train, target, ctx.seed, ctx.scale < 0.3);
        let path = out.join(format!("{}.json", target.name()));
        m.save(&path)?;
        println!(
            "{}: winner={} test-MRE={:.2}% -> {}",
            target.name(),
            m.report.winner.name(),
            m.mre_on(&test) * 100.0,
            path.display()
        );
    }
    Ok(())
}

fn parse_config(args: &Args) -> dnnabacus::Result<TrainConfig> {
    let dataset = match args.str_or("dataset", "cifar100").as_str() {
        "mnist" => DatasetKind::Mnist,
        _ => DatasetKind::Cifar100,
    };
    Ok(TrainConfig {
        dataset,
        batch: args.usize_or("batch", 128),
        data_fraction: args.f64_or("data-fraction", 0.1),
        epochs: args.usize_or("epochs", 1),
        lr: args.f64_or("lr", 0.1),
        optimizer: Optimizer::by_name(&args.str_or("optimizer", "sgd-momentum"))?,
        framework: match args.str_or("framework", "pytorch").as_str() {
            "tensorflow" => Framework::TfSim,
            _ => Framework::TorchSim,
        },
        device: DeviceProfile::by_name(&args.str_or("device", "rtx2080"))?,
        seed: args.u64_or("seed", 0),
    })
}

fn predict(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let model_name = args.str_or("model", "vgg16");
    let cfg = parse_config(args)?;
    let corpus = ctx.training_corpus();
    let time_model = AutoMl::train_opt(&corpus, Target::Time, ctx.seed, true);
    let mem_model = AutoMl::train_opt(&corpus, Target::Memory, ctx.seed, true);
    let g = zoo::build(
        &model_name,
        cfg.dataset.in_channels(),
        cfg.dataset.classes(),
    )?;
    let f = dnnabacus::features::feature_vector(&g, &cfg, dnnabacus::features::StructureRep::Nsm);
    let (pt, pm) = (time_model.predict(&f), mem_model.predict(&f));
    println!(
        "predicted: time {:.2}s, memory {:.0} MiB",
        pt,
        pm / (1u64 << 20) as f64
    );
    match dnnabacus::sim::simulate_training(&g, &cfg) {
        Ok(m) => println!(
            "simulated: time {:.2}s, memory {:.0} MiB  (rel err {:.2}% / {:.2}%)",
            m.total_time,
            (m.peak_mem >> 20) as f64,
            ((pt - m.total_time) / m.total_time).abs() * 100.0,
            ((pm - m.peak_mem as f64) / m.peak_mem as f64).abs() * 100.0
        ),
        Err(e) => println!("simulated: {e}"),
    }
    Ok(())
}

fn serve(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let n_requests = args.usize_or("requests", 256);
    let defaults = ServiceConfig::default();
    let svc_cfg = ServiceConfig {
        workers: args.usize_or("workers", defaults.workers),
        cache_capacity: args.usize_or("cache-capacity", defaults.cache_capacity),
        cache_ttl: Duration::from_millis(
            args.u64_or("cache-ttl-ms", defaults.cache_ttl.as_millis() as u64),
        ),
        ..defaults
    };
    let backend: Arc<dyn dnnabacus::coordinator::CostModel> =
        match args.str_or("backend", "automl").as_str() {
            "mlp" => Arc::new(MlpBackend::spawn(ctx.seed)?),
            _ => {
                let corpus = ctx.training_corpus();
                Arc::new(AutoMlBackend {
                    time_model: AutoMl::train_opt(&corpus, Target::Time, ctx.seed, true),
                    memory_model: AutoMl::train_opt(&corpus, Target::Memory, ctx.seed, true),
                })
            }
        };
    println!("backend: {}", backend.name());
    let svc = PredictionService::start(svc_cfg, backend);
    let names: Vec<&str> = zoo::CLASSIC_29.iter().map(|(n, _)| *n).collect();
    let batches = [32usize, 64, 128, 256];
    // A skewed (Zipf-ish) mix: schedulers resubmit recurring job shapes,
    // which is exactly what the content-keyed cache absorbs.
    let mut rng = Rng::new(ctx.seed);
    let requests: Vec<PredictRequest> = (0..n_requests)
        .map(|i| {
            let dataset = if rng.chance(0.5) {
                DatasetKind::Cifar100
            } else {
                DatasetKind::Mnist
            };
            PredictRequest {
                id: i as u64,
                model: names[rng.zipf(names.len())].to_string(),
                config: TrainConfig::paper_default(dataset, batches[rng.zipf(batches.len())]),
            }
        })
        .collect();
    // Submit in waves so later waves can hit cache entries earlier waves
    // filled (an open-loop blast would finish submitting before the
    // first fill and never hit).
    let t0 = std::time::Instant::now();
    let mut ok = 0;
    for wave in requests.chunks(64) {
        let rxs: Vec<_> = wave.iter().map(|r| svc.submit(r.clone())).collect();
        for rx in rxs {
            if rx.recv()?.is_ok() {
                ok += 1;
            }
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = svc.shutdown();
    println!(
        "served {ok}/{n_requests} in {elapsed:.2}s ({:.0} req/s) | p50 {:.2}ms p99 {:.2}ms | mean batch {:.1}",
        ok as f64 / elapsed,
        m.p50_latency_s * 1e3,
        m.p99_latency_s * 1e3,
        m.mean_batch_size
    );
    println!(
        "cache: {} hits / {} misses | batcher: {} batches, {} steals",
        m.cache_hits, m.cache_misses, m.batches, m.steals
    );
    Ok(())
}

fn nsm_demo(args: &Args) -> dnnabacus::Result<()> {
    let model = args.str_or("model", "resnet18");
    let g = zoo::build(&model, 3, 100)?;
    let nsm = Nsm::build(&g);
    println!(
        "NSM of {model} ({} nodes, {} edges):",
        g.len(),
        g.edge_count()
    );
    println!("{}", nsm.render());
    Ok(())
}
