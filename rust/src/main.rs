//! `dnnabacus` — the command-line launcher.
//!
//! ```text
//! dnnabacus <command> [--flags]
//!
//! Experiments (regenerate the paper's tables/figures):
//!   table1 fig1 fig2 fig3 fig4 fig8 fig9 fig10 fig11 fig12 fig13 fig14
//!   headline        overall test MRE (paper: 0.9% time / 2.8% memory)
//!   ablation        structure-independent features vs + NSM
//!   all             every experiment above except fig13/ablation (slow)
//!
//! Pipeline:
//!   collect         run the profiling sweeps, write dataset JSON
//!   train           train AutoML predictors, write model JSON
//!   predict         predict one (model, config) cost
//!   predict-spec    predict a user-defined network from a spec file
//!                   (dnnabacus-spec-v1/-v2 JSON; see README "Model
//!                   specs" — v2 adds token-sequence inputs and the
//!                   transformer ops)
//!   export-spec     write a zoo network as a spec file (--model, --out)
//!   lint            static-analyze a network without predicting:
//!                   --spec FILE (or positional) | --model NAME|all;
//!                   prints DA0xx findings, exit 1 on error severity
//!   serve           run the prediction service: in-process load
//!                   generator by default, or a real TCP server with
//!                   --listen ADDR (dnnabacus-wire-v1)
//!   client          predict against a remote `serve --listen` server
//!                   (--addr HOST:PORT, --model NAME or --spec FILE)
//!   fleet           place a streaming job mix onto an N-device cluster
//!                   with predicted costs (--devices, --jobs, --policy,
//!                   --arrival-rate, --specs DIR, --json)
//!   stats           render the unified metrics snapshot: scrape a live
//!                   server (--addr HOST:PORT, --watch SECS) or run a
//!                   seeded local load and report it (--json, --last K)
//!   eval            unseen-hardware harness: train on every device
//!                   profile except --holdout, measure zero-shot vs
//!                   few-shot-calibrated MRE (--shots, --json [PATH])
//!   nsm-demo        print the NSM of a model (paper Figures 6-7)
//!
//! Common flags: --scale 0.35 --seed 42 --out dir --model vgg16
//!               --batch 128 --dataset cifar100|mnist|sst2 --device rtx2080
//!               --framework pytorch|tensorflow --backend automl|mlp
//!               --json (predict/predict-spec/client/serve --listen:
//!               machine-readable output)
//!
//! `serve` flags: --requests 256 --workers 2 --cache-capacity 4096
//!                --cache-ttl-ms 120000   (capacity 0 disables caching)
//!                --specs DIR (mix spec files from DIR into the load)
//!                --listen ADDR (serve TCP; port 0 = OS-assigned)
//!                --max-inflight 256 --max-conns 4096
//!                --max-frame BYTES (request payload cap, default 4 MiB)
//!                --frame-deadline-ms 10000 (slow-loris/stalled-peer cap)
//!                --serve-requests N (answer N requests, drain, exit)
//!                --trace-sample N (trace 1-in-N predicts; default 1,
//!                0 disables request-lifecycle tracing)
//!
//! `client` flags: --addr HOST:PORT --count N (pipelined repeats)
//!                 plus the common config flags, forwarded per request
//!
//! `stats` flags:  --addr HOST:PORT (scrape a live server; otherwise a
//!                 seeded local run) --watch SECS (re-scrape forever)
//!                 --last K (trace summaries to fetch, default 8)
//!                 --requests N (local-run load size, default 96) --json
//!
//! `fleet` flags:  --devices rtx2080x2,rtx3090 --jobs 20
//!                 --policy first-fit|best-fit-memory|least-finish|ga|all
//!                 --arrival-rate 0.05 (mean jobs per simulated second;
//!                 0 = all at once) --specs DIR --json
//!
//! `lint` flags:   --spec FILE | --model NAME (or `all` for the whole
//!                 zoo) --batch N (analysis batch; default 128) --json
//!
//! `eval` flags:   --holdout rtx3090 (device profile to hold out)
//!                 --shots 64 (residuals granted to the calibrator)
//!                 --json [PATH] (write the BENCH_*-schema report to
//!                 PATH, or to stdout with a bare --json)
//!
//! `--backend mlp` needs the AOT artifacts (python/compile/aot.py) and a
//! PJRT binding; this zero-dependency build ships a stub backend, so the
//! default `automl` backend is the serving path.
//! ```

// The launcher glues subsystems together; its arithmetic is display
// math (percentages, MiB conversions), not cost accounting.
#![allow(clippy::arithmetic_side_effects)]

use dnnabacus::analyze;
use dnnabacus::coordinator::{
    fits_device,
    service::{AutoMlBackend, MlpBackend},
    PredictRequest, PredictionService, ServiceConfig,
};
use dnnabacus::experiments::{self, Ctx};
use dnnabacus::features::Nsm;
use dnnabacus::fleet;
use dnnabacus::graph::Graph;
use dnnabacus::ingest::{self, ParsedSpec};
use dnnabacus::net::{self, WireModel, WireRequest, WireResponse};
use dnnabacus::obs;
use dnnabacus::predictor::{AutoMl, Target};
use dnnabacus::sim::{DatasetKind, TrainConfig};
use dnnabacus::util::cli::Args;
use dnnabacus::util::error::Context as _;
use dnnabacus::util::json::Json;
use dnnabacus::util::prng::Rng;
use dnnabacus::zoo;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let result = match args.command.as_deref() {
        Some("all") => run_all(&args),
        Some("collect") => collect(&args),
        Some("train") => train(&args),
        Some("predict") => predict(&args),
        Some("predict-spec") => predict_spec(&args),
        Some("export-spec") => export_spec(&args),
        Some("lint") => lint(&args),
        Some("serve") => serve(&args),
        Some("client") => client(&args),
        Some("fleet") => fleet(&args),
        Some("stats") => stats(&args),
        Some("eval") => eval(&args),
        Some("nsm-demo") => nsm_demo(&args),
        Some(cmd) => run_experiment(cmd, &args),
        None => {
            eprintln!("usage: dnnabacus <command> [--flags]; see the README");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from(args: &Args) -> Ctx {
    Ctx {
        scale: args.f64_or("scale", 0.25),
        seed: args.u64_or("seed", 0xDA7A),
        cache_dir: Some(PathBuf::from(
            args.str_or("cache-dir", "target/dnnabacus-cache"),
        )),
    }
}

fn run_experiment(name: &str, args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    for table in experiments::run(name, &ctx)? {
        println!("{}", table.render());
        if args.bool("csv") {
            println!("{}", table.to_csv());
        }
    }
    Ok(())
}

fn run_all(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    for name in experiments::ALL_EXPERIMENTS {
        println!("==== {name} ====");
        for table in experiments::run(name, &ctx)? {
            println!("{}", table.render());
        }
    }
    println!("==== headline ====");
    for table in experiments::run("headline", &ctx)? {
        println!("{}", table.render());
    }
    Ok(())
}

fn collect(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let out = PathBuf::from(args.str_or("out", "target/dnnabacus-data"));
    std::fs::create_dir_all(&out)?;
    let classic = ctx.classic_dataset();
    classic.save(&out.join("classic.json"))?;
    println!(
        "classic sweep: {} points -> {}",
        classic.len(),
        out.join("classic.json").display()
    );
    let random = ctx.random_dataset();
    random.save(&out.join("random.json"))?;
    println!("random sweep: {} points", random.len());
    let unseen = ctx.unseen_dataset();
    unseen.save(&out.join("unseen.json"))?;
    println!("unseen sweep: {} points", unseen.len());
    Ok(())
}

fn train(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let out = PathBuf::from(args.str_or("out", "target/dnnabacus-models"));
    std::fs::create_dir_all(&out)?;
    let corpus = ctx.training_corpus();
    let (train, test) = corpus.split(0.7, ctx.seed);
    for target in [Target::Time, Target::Memory] {
        let m = AutoMl::train_opt(&train, target, ctx.seed, ctx.scale < 0.3);
        let path = out.join(format!("{}.json", target.name()));
        m.save(&path)?;
        println!(
            "{}: winner={} test-MRE={:.2}% -> {}",
            target.name(),
            m.report.winner.name(),
            m.mre_on(&test) * 100.0,
            path.display()
        );
    }
    Ok(())
}

/// Interpret the config flags through the same strict single
/// interpreter the wire protocol uses (`net::proto::config_from`), so
/// `--dataset`/`--framework`/… mean exactly the same thing locally and
/// remotely — unknown values are errors, not silent fallbacks.
fn parse_config(args: &Args) -> dnnabacus::Result<TrainConfig> {
    let dataset = match args.get("dataset") {
        None => DatasetKind::Cifar100,
        Some(name) => dnnabacus::net::proto::dataset_by_name(name)?,
    };
    dnnabacus::net::proto::config_from(&overrides_from(args)?, dataset)
}

fn predict(args: &Args) -> dnnabacus::Result<()> {
    let model_name = args.str_or("model", "vgg16");
    let cfg = parse_config(args)?;
    let g = zoo::build(
        &model_name,
        cfg.dataset.in_channels(),
        cfg.dataset.classes(),
    )?;
    predict_graph(args, &model_name, &g, &cfg)
}

fn predict_spec(args: &Args) -> dnnabacus::Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("spec"))
        .ok_or_else(|| dnnabacus::err!("usage: dnnabacus predict-spec <file.json> [--flags]"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let parsed = ingest::compile_str(&text).with_context(|| format!("spec {path}"))?;
    // Non-fatal analyzer findings go to stderr so --json stdout stays
    // machine-readable; `dnnabacus lint` gives the full report.
    for d in &parsed.warnings {
        eprintln!("spec {path}: {}", d.render());
    }
    let mut cfg = parse_config(args)?;
    // Default the dataset to the one matching the spec's declared input
    // geometry, so `predict-spec file.json` just works for MNIST-shaped
    // nets; an explicit --dataset always wins (and is checked).
    if args.get("dataset").is_none() {
        if let Some(dataset) = parsed.matching_dataset() {
            cfg.dataset = dataset;
        }
    }
    parsed.check_dataset(cfg.dataset)?;
    predict_graph(args, &parsed.name, &parsed.graph, &cfg)
}

fn export_spec(args: &Args) -> dnnabacus::Result<()> {
    let model = args.str_or("model", "vgg16");
    let cfg = parse_config(args)?;
    let spec = ingest::spec_for_zoo(&model, cfg.dataset.in_channels(), cfg.dataset.classes())?;
    let text = spec.to_json().to_string();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
            println!("wrote {model} spec -> {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// `lint`: run the multi-pass static analyzer over a spec file or zoo
/// network(s) without training or predicting anything, and print every
/// finding with its stable `DA0xx` code. Exit status is 1 when any
/// error-severity finding is present, so the command gates CI directly.
fn lint(args: &Args) -> dnnabacus::Result<()> {
    let spec_path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("spec"));
    if spec_path.is_some() && args.get("model").is_some() {
        dnnabacus::bail!("pass either --spec FILE or --model NAME, not both");
    }
    // The analyzer walks concrete shapes; `Flatten` folds the spatial
    // dims per sample, so a zero batch has no meaning here.
    let batch = match args.get("batch") {
        None => None,
        Some(raw) => {
            let b: usize = raw
                .parse()
                .map_err(|_| dnnabacus::err!("--batch expects a positive integer, got '{raw}'"))?;
            dnnabacus::ensure!(b >= 1, "--batch must be at least 1");
            Some(b)
        }
    };
    let with_batch = |opts: analyze::Options| match batch {
        Some(b) => opts.with_batch(b),
        None => opts,
    };
    type Timing = Vec<(&'static str, u64)>;
    let mut targets: Vec<(String, analyze::Report, Timing)> = Vec::new();
    if let Some(path) = spec_path {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let spec = ingest::ModelSpec::parse_str(&text).with_context(|| format!("spec {path}"))?;
        let opts = with_batch(analyze::Options::for_input(
            spec.input.channels,
            spec.input.hw,
        ));
        let (report, timing) =
            analyze::run_spec_timed(&spec, &opts).with_context(|| format!("spec {path}"))?;
        targets.push((path.to_string(), report, timing));
    } else {
        let model = args.str_or("model", "all");
        let names: Vec<String> = match model.as_str() {
            "all" => zoo::all_names().into_iter().map(String::from).collect(),
            _ => vec![model],
        };
        for name in names {
            let g = zoo::build(&name, 3, 100)?;
            let opts = with_batch(analyze::Options::for_graph(&g));
            let (report, timing) = analyze::run_graph_timed(&g, &opts);
            targets.push((name, report, timing));
        }
    }
    let errors: usize = targets
        .iter()
        .map(|(_, r, _)| r.count(analyze::Severity::Error))
        .sum();
    let warnings: usize = targets
        .iter()
        .map(|(_, r, _)| r.count(analyze::Severity::Warn))
        .sum();
    if args.bool("json") {
        let rows: Vec<Json> = targets
            .iter()
            .map(|(name, r, timing)| {
                // Per-pass wall microseconds, measured through the same
                // obs span machinery the server's request traces use.
                let mut passes = Json::obj();
                for (pass, us) in timing {
                    passes.set(*pass, *us);
                }
                let mut t = Json::obj();
                t.set("target", name.as_str())
                    .set(
                        "diagnostics",
                        Json::Arr(r.diagnostics.iter().map(|d| d.to_json()).collect()),
                    )
                    .set("errors", r.count(analyze::Severity::Error))
                    .set("warnings", r.count(analyze::Severity::Warn))
                    .set("timing", passes);
                t
            })
            .collect();
        let mut o = Json::obj();
        o.set("targets", Json::Arr(rows))
            .set("errors", errors)
            .set("warnings", warnings);
        println!("{o}");
    } else {
        for (name, r, _) in &targets {
            if r.is_empty() {
                println!("{name}: clean");
            } else {
                println!("{name}:");
                for d in &r.diagnostics {
                    println!("  {}", d.render());
                }
            }
        }
        println!(
            "{} target(s): {errors} error(s), {warnings} warning(s)",
            targets.len()
        );
    }
    dnnabacus::ensure!(errors == 0, "lint: {errors} error(s)");
    Ok(())
}

/// Shared tail of `predict` / `predict-spec`: train the AutoML models,
/// predict over the given graph, cross-check against the simulator, and
/// report as prose or (with --json) as one machine-readable object.
fn predict_graph(args: &Args, name: &str, g: &Graph, cfg: &TrainConfig) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let corpus = ctx.training_corpus();
    let time_model = AutoMl::train_opt(&corpus, Target::Time, ctx.seed, true);
    let mem_model = AutoMl::train_opt(&corpus, Target::Memory, ctx.seed, true);
    let f = dnnabacus::features::feature_vector(g, cfg, dnnabacus::features::StructureRep::Nsm);
    let (pt, pm) = (time_model.predict(&f), mem_model.predict(&f));
    let fits = fits_device(&cfg.device, pm);
    let sim = dnnabacus::sim::simulate_training(g, cfg);
    if args.bool("json") {
        let mut predicted = Json::obj();
        predicted
            .set("time_s", pt)
            .set("memory_bytes", pm)
            .set("fits_device", fits);
        let mut o = Json::obj();
        o.set("model", name)
            .set("dataset", cfg.dataset.name())
            .set("batch", cfg.batch)
            .set("device", cfg.device.name.as_str())
            .set("params", g.param_count())
            .set("weighted_layers", g.weighted_layers())
            .set(
                "flops_per_sample",
                g.flops_per_sample(cfg.dataset.in_channels(), cfg.dataset.hw())
                    .unwrap_or(0),
            )
            .set("predicted", predicted);
        match sim {
            Ok(m) => {
                let mut s = Json::obj();
                s.set("time_s", m.total_time)
                    .set("memory_bytes", m.peak_mem);
                o.set("simulated", s);
            }
            Err(_) => {
                o.set("simulated", Json::Null);
            }
        }
        println!("{o}");
        return Ok(());
    }
    println!(
        "{name}: {} params, {} weighted layers",
        g.param_count(),
        g.weighted_layers()
    );
    println!(
        "predicted: time {:.2}s, memory {:.0} MiB{}",
        pt,
        pm / (1u64 << 20) as f64,
        if fits { "" } else { "  [would NOT fit device]" }
    );
    match sim {
        Ok(m) => println!(
            "simulated: time {:.2}s, memory {:.0} MiB  (rel err {:.2}% / {:.2}%)",
            m.total_time,
            (m.peak_mem >> 20) as f64,
            ((pt - m.total_time) / m.total_time).abs() * 100.0,
            ((pm - m.peak_mem as f64) / m.peak_mem as f64).abs() * 100.0
        ),
        Err(e) => println!("simulated: {e}"),
    }
    Ok(())
}

/// Service configuration shared by the load-generator and `--listen`
/// modes of `serve`.
fn service_config(args: &Args) -> ServiceConfig {
    let defaults = ServiceConfig::default();
    ServiceConfig {
        workers: args.usize_or("workers", defaults.workers),
        cache_capacity: args.usize_or("cache-capacity", defaults.cache_capacity),
        cache_ttl: Duration::from_millis(
            args.u64_or("cache-ttl-ms", defaults.cache_ttl.as_millis() as u64),
        ),
        max_inflight: args.usize_or("max-inflight", defaults.max_inflight),
        ..defaults
    }
}

/// Build the prediction backend (`--backend automl|mlp`).
fn backend_from(
    args: &Args,
    ctx: &Ctx,
) -> dnnabacus::Result<Arc<dyn dnnabacus::coordinator::CostModel>> {
    let backend: Arc<dyn dnnabacus::coordinator::CostModel> =
        match args.str_or("backend", "automl").as_str() {
            "mlp" => Arc::new(MlpBackend::spawn(ctx.seed)?),
            _ => {
                let corpus = ctx.training_corpus();
                Arc::new(AutoMlBackend {
                    time_model: AutoMl::train_opt(&corpus, Target::Time, ctx.seed, true),
                    memory_model: AutoMl::train_opt(&corpus, Target::Memory, ctx.seed, true),
                })
            }
        };
    Ok(backend)
}

fn serve(args: &Args) -> dnnabacus::Result<()> {
    if args.get("listen").is_some() {
        return serve_listen(args);
    }
    let ctx = ctx_from(args);
    let n_requests = args.usize_or("requests", 256);
    let svc_cfg = service_config(args);
    let backend = backend_from(args, &ctx)?;
    println!("backend: {}", backend.name());
    // Arc-wrapped so the zipf mix below clones a pointer per request,
    // not a graph.
    let specs: Vec<Arc<ParsedSpec>> = load_spec_dir(args, false)?
        .into_iter()
        .map(Arc::new)
        .collect();
    let svc = PredictionService::start(svc_cfg, backend);
    let names: Vec<&str> = zoo::CLASSIC_29.iter().map(|(n, _)| *n).collect();
    let batches = [32usize, 64, 128, 256];
    // A skewed (Zipf-ish) mix: schedulers resubmit recurring job shapes,
    // which is exactly what the content-keyed cache absorbs. With
    // --specs, a third of the stream arrives as user-defined networks.
    let mut rng = Rng::new(ctx.seed);
    let requests: Vec<PredictRequest> = (0..n_requests)
        .map(|i| {
            let batch = batches[rng.zipf(batches.len())];
            if !specs.is_empty() && rng.chance(1.0 / 3.0) {
                let p = specs[rng.zipf(specs.len())].clone();
                let dataset = p.matching_dataset().unwrap_or(DatasetKind::Cifar100);
                PredictRequest::spec(i as u64, p, TrainConfig::paper_default(dataset, batch))
            } else {
                let dataset = if rng.chance(0.5) {
                    DatasetKind::Cifar100
                } else {
                    DatasetKind::Mnist
                };
                let name = names[rng.zipf(names.len())];
                PredictRequest::zoo(i as u64, name, TrainConfig::paper_default(dataset, batch))
            }
        })
        .collect();
    // Submit in waves so later waves can hit cache entries earlier waves
    // filled (an open-loop blast would finish submitting before the
    // first fill and never hit).
    let t0 = std::time::Instant::now();
    let mut ok = 0;
    for wave in requests.chunks(64) {
        let rxs: Vec<_> = wave.iter().map(|r| svc.submit(r.clone())).collect();
        for rx in rxs {
            if rx.recv()?.is_ok() {
                ok += 1;
            }
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = svc.shutdown();
    println!(
        "served {ok}/{n_requests} in {elapsed:.2}s ({:.0} req/s) | p50 {:.2}ms p99 {:.2}ms | mean batch {:.1}",
        ok as f64 / elapsed,
        m.p50_latency_s * 1e3,
        m.p99_latency_s * 1e3,
        m.mean_batch_size
    );
    println!(
        "cache: {} hits / {} misses | batcher: {} batches, {} steals",
        m.cache_hits, m.cache_misses, m.batches, m.steals
    );
    Ok(())
}

/// `serve --listen ADDR`: host the prediction service behind the
/// `dnnabacus-wire-v1` TCP front door. With `--serve-requests N` the
/// server answers N requests, drains gracefully, prints a summary
/// (JSON with `--json`) and exits — the CI smoke rides on that; without
/// it the server runs until killed.
fn serve_listen(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let addr = match args.get("listen") {
        // A bare `--listen` parses as the boolean "true".
        None | Some("true") => "127.0.0.1:9377".to_string(),
        Some(a) => a.to_string(),
    };
    let mut svc_cfg = service_config(args);
    if args.get("max-inflight").is_none() {
        // A network front door needs a bound by default; 0 would accept
        // unboundedly and defeat the overload protocol.
        svc_cfg.max_inflight = 256;
    }
    let backend = backend_from(args, &ctx)?;
    println!("backend: {}", backend.name());
    let defaults = net::ServerConfig::default();
    let svc = PredictionService::start(svc_cfg, backend);
    let server = net::Server::builder()
        .max_conns(args.usize_or("max-conns", defaults.max_conns))
        .max_frame(args.usize_or("max-frame", defaults.max_frame))
        .frame_deadline(Duration::from_millis(args.u64_or(
            "frame-deadline-ms",
            defaults.frame_deadline.as_millis() as u64,
        )))
        .trace_sample(args.u64_or("trace-sample", defaults.trace_sample))
        .start(&addr, svc)?;
    println!("listening on {} ({})", server.local_addr(), net::WIRE_FORMAT);
    // Stdout is block-buffered when redirected; the CI smoke greps this
    // line from a file while the server is still running.
    std::io::stdout().flush()?;
    let budget = args
        .get("serve-requests")
        .map(|s| {
            s.parse::<u64>()
                .map_err(|_| dnnabacus::err!("--serve-requests must be an integer, got '{s}'"))
        })
        .transpose()?;
    let Some(budget) = budget else {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    };
    while server.answered() < budget {
        std::thread::sleep(Duration::from_millis(10));
    }
    // The unified snapshot must be read before shutdown tears the
    // service (and its registry's gauge sources) down.
    let snapshot = server.snapshot();
    let (wire, m) = server.shutdown();
    if args.bool("json") {
        let mut w = Json::obj();
        w.set("connections", wire.connections)
            .set("conns_rejected", wire.conns_rejected)
            .set("peak_conns", wire.peak_conns)
            .set("requests", wire.requests)
            .set("answered", wire.answered)
            .set("overloaded", wire.overloaded)
            .set("bad_requests", wire.bad_requests)
            .set("io_errors", wire.io_errors)
            .set("schedules", wire.schedules);
        let mut s = Json::obj();
        s.set("served", m.served)
            .set("errors", m.errors)
            .set("cache_hits", m.cache_hits)
            .set("cache_misses", m.cache_misses)
            .set("overload_rejected", m.overload_rejected)
            .set("p50_latency_s", m.p50_latency_s)
            .set("p99_latency_s", m.p99_latency_s);
        let mut o = Json::obj();
        o.set("wire", w)
            .set("service", s)
            .set("accuracy", obs::block_from_snapshot(&snapshot))
            .set("metrics", snapshot);
        println!("{o}");
    } else {
        println!(
            "answered {} requests ({} overloaded, {} bad) over {} connections",
            wire.answered, wire.overloaded, wire.bad_requests, wire.connections
        );
        println!(
            "cache: {} hits / {} misses | p50 {:.2} ms p99 {:.2} ms",
            m.cache_hits,
            m.cache_misses,
            m.p50_latency_s * 1e3,
            m.p99_latency_s * 1e3
        );
        print!("{}", obs::render_block(&obs::block_from_snapshot(&snapshot)));
    }
    Ok(())
}

/// `client`: predict a zoo name or a spec file against a remote
/// `serve --listen` server. `--count N` pipelines N copies of the
/// request over one connection (ids 0..N).
fn client(args: &Args) -> dnnabacus::Result<()> {
    let addr = args.get("addr").ok_or_else(|| {
        dnnabacus::err!(
            "usage: dnnabacus client --addr HOST:PORT [--model NAME | --spec FILE] \
             [--count N] [--json] [config flags]"
        )
    })?;
    let model = match (args.get("spec"), args.get("model")) {
        // Mirror the wire protocol's strictness: an ambiguous request
        // is an error, not a silent preference for one of the two.
        (Some(_), Some(_)) => {
            dnnabacus::bail!("pass either --model or --spec, not both")
        }
        (Some(path), None) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            WireModel::Spec(Json::parse(&text).with_context(|| format!("spec {path}"))?)
        }
        (None, explicit) => WireModel::Zoo(explicit.unwrap_or("vgg16").to_string()),
    };
    let overrides = overrides_from(args)?;
    let count = args.usize_or("count", 1).max(1);
    let requests: Vec<WireRequest> = (0..count)
        .map(|i| WireRequest {
            id: i as u64,
            model: model.clone(),
            overrides: overrides.clone(),
        })
        .collect();
    let mut client = net::Client::connect(addr)?;
    let t0 = std::time::Instant::now();
    let responses = client.call_many(&requests)?;
    let elapsed = t0.elapsed().as_secs_f64();
    let failed = responses.iter().filter(|r| !r.is_ok()).count();
    if args.bool("json") {
        if count == 1 {
            println!("{}", responses[0].to_json());
        } else {
            let mut o = Json::obj();
            o.set("count", count)
                .set("elapsed_s", elapsed)
                .set("failed", failed)
                .set(
                    "responses",
                    Json::Arr(responses.iter().map(WireResponse::to_json).collect()),
                );
            println!("{o}");
        }
    } else {
        for resp in &responses {
            match resp {
                WireResponse::Ok {
                    model,
                    prediction,
                    diagnostics,
                } => {
                    println!(
                        "{model}: time {:.2}s, memory {:.0} MiB{} (service latency {:.2} ms)",
                        prediction.time_s,
                        prediction.memory_bytes / (1u64 << 20) as f64,
                        if prediction.fits_device {
                            ""
                        } else {
                            "  [would NOT fit device]"
                        },
                        prediction.latency_s * 1e3,
                    );
                    // Server-side analyzer findings ride the response;
                    // show them the way `lint` would, indented.
                    for d in diagnostics {
                        let field = |key| d.get(key).and_then(Json::as_str);
                        let sev = field("severity").unwrap_or("warn");
                        let code = field("code").unwrap_or("DA???");
                        let msg = field("message").unwrap_or_default();
                        match field("layer") {
                            Some(layer) => {
                                eprintln!("  {sev} {code} layer '{layer}': {msg}")
                            }
                            None => eprintln!("  {sev} {code}: {msg}"),
                        }
                    }
                }
                // `client` only sends predict requests; a schedule or
                // metrics reply would be a server bug — surface it raw.
                WireResponse::Schedule { id, report } => {
                    println!("request {id}: unexpected schedule report {report}")
                }
                WireResponse::Metrics { id, snapshot, .. } => {
                    println!("request {id}: unexpected metrics snapshot {snapshot}")
                }
                WireResponse::Err { id, kind, message } => {
                    eprintln!("request {id}: {} — {message}", kind.as_str())
                }
            }
        }
        if count > 1 {
            println!(
                "{count} requests in {elapsed:.3}s ({:.0} req/s), {failed} failed",
                count as f64 / elapsed
            );
        }
    }
    dnnabacus::ensure!(failed == 0, "{failed}/{count} requests failed");
    Ok(())
}

/// `fleet`: place a deterministic streaming job mix onto an N-device
/// cluster with predicted costs, one run per requested policy, and
/// report makespan / utilization / waits / regret. `--policy all`
/// (the default) compares every policy on the identical workload.
fn fleet(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let cluster = fleet::Cluster::parse(&args.str_or("devices", "rtx2080,rtx3090"))?;
    let n_jobs = args.usize_or("jobs", 20);
    let arrival_rate = args.f64_or("arrival-rate", 0.05);
    let json = args.bool("json");
    let kinds: Vec<fleet::PolicyKind> = match args.str_or("policy", "all").as_str() {
        "all" => fleet::PolicyKind::ALL.to_vec(),
        name => vec![fleet::PolicyKind::parse(name)?],
    };
    let specs: Vec<Arc<ParsedSpec>> = load_spec_dir(args, json)?
        .into_iter()
        .map(Arc::new)
        .collect();
    let jobs = fleet::job_mix(n_jobs, ctx.seed, &specs);
    let backend = backend_from(args, &ctx)?;
    if !json {
        println!("backend: {}", backend.name());
    }
    let svc = PredictionService::start(service_config(args), backend);
    // Fleet counters ride the service's registry so the `--json`
    // snapshot is the same unified key set `serve --json` emits.
    let registry = svc.registry();
    fleet::register_metrics(&registry);
    let ledger = Arc::new(obs::AccuracyLedger::register(&registry, ctx.seed));
    // Wrap the service costs in the calibration seam: every placement's
    // observed ground truth lands in the residual ledger, and later
    // predictions consume the per-device affine correction.
    let mut service_costs = fleet::ServiceCosts::new(&svc);
    let mut costs = fleet::CalibratedCosts::new(&mut service_costs, Arc::clone(&ledger));
    let params = fleet::SimParams {
        seed: ctx.seed,
        arrival_rate,
        mem_safety: fleet::MEM_SAFETY,
    };
    let mut reports = Vec::with_capacity(kinds.len());
    for kind in kinds {
        let mut policy = fleet::make_policy(kind, ctx.seed);
        reports.push(fleet::run_with_registry(
            &cluster,
            &jobs,
            policy.as_mut(),
            &mut costs,
            &params,
            &registry,
        )?);
    }
    // `costs` borrows the service; release it before the move-out drain.
    drop(costs);
    drop(service_costs);
    svc.refresh_gauges();
    let snapshot = registry.snapshot();
    let m = svc.shutdown();
    if json {
        let mut o = Json::obj();
        o.set("devices", args.str_or("devices", "rtx2080,rtx3090").as_str())
            .set("jobs", n_jobs)
            .set("seed", ctx.seed)
            .set("arrival_rate", arrival_rate)
            .set("cache_hits", m.cache_hits)
            .set("cache_misses", m.cache_misses)
            .set(
                "reports",
                Json::Arr(reports.iter().map(fleet::FleetReport::to_json).collect()),
            )
            .set("accuracy", obs::block_from_snapshot(&snapshot))
            .set("metrics", snapshot);
        println!("{o}");
    } else {
        for r in &reports {
            println!("{}", r.render());
        }
        if reports.len() > 1 {
            println!("{}", fleet::comparison_table(&reports).render());
        }
        println!(
            "prediction cache over {} cost queries: {} hits / {} misses",
            m.served, m.cache_hits, m.cache_misses
        );
    }
    Ok(())
}

/// `stats`: render the unified observability snapshot. With `--addr` it
/// scrapes a running `serve --listen` server through the wire `metrics`
/// request (`--watch SECS` re-scrapes forever, clearing the screen
/// between rounds); without it, a short seeded Zipf load runs through an
/// in-process server — the same real-socket path — and its snapshot is
/// reported.
fn stats(args: &Args) -> dnnabacus::Result<()> {
    let json = args.bool("json");
    let last = args.usize_or("last", net::proto::DEFAULT_METRICS_LAST);
    if let Some(addr) = args.get("addr") {
        let watch: Option<u64> = args
            .get("watch")
            .map(|s| {
                s.parse()
                    .map_err(|_| dnnabacus::err!("--watch expects seconds, got '{s}'"))
            })
            .transpose()?;
        let mut client = net::Client::connect(addr)?;
        let mut scrape_id = 0u64;
        loop {
            let (snapshot, traces) = match client.metrics(scrape_id, last)? {
                WireResponse::Metrics {
                    snapshot, traces, ..
                } => (snapshot, traces),
                other => dnnabacus::bail!("expected a metrics reply, got {}", other.to_json()),
            };
            if json {
                let mut o = Json::obj();
                o.set("accuracy", obs::block_from_snapshot(&snapshot))
                    .set("snapshot", snapshot)
                    .set("traces", Json::Arr(traces));
                println!("{o}");
            } else {
                if watch.is_some() {
                    // ANSI clear + home: a poor man's dashboard.
                    print!("\x1b[2J\x1b[H");
                }
                print_stats_text(&snapshot, &traces);
            }
            std::io::stdout().flush()?;
            match watch {
                Some(secs) => std::thread::sleep(Duration::from_secs(secs.max(1))),
                None => return Ok(()),
            }
            scrape_id += 1;
        }
    }
    // Local mode: drive a seeded load through an in-process server over
    // a real socket with every request traced, then scrape it exactly
    // the way the remote path would.
    let mut ctx = ctx_from(args);
    if args.get("scale").is_none() {
        // A quick demo corpus; prediction quality is not the point here.
        ctx.scale = 0.05;
    }
    let backend = backend_from(args, &ctx)?;
    eprintln!(
        "backend: {} (local run; pass --addr to scrape a live server)",
        backend.name()
    );
    let svc = PredictionService::start(service_config(args), backend);
    let server = net::Server::builder()
        .trace_sample(1)
        .start("127.0.0.1:0", svc)?;
    let n = args.usize_or("requests", 96);
    let names: Vec<&str> = zoo::CLASSIC_29.iter().map(|(name, _)| *name).collect();
    let batches = [32usize, 64, 128, 256];
    let mut rng = Rng::new(ctx.seed);
    let requests: Vec<WireRequest> = (0..n)
        .map(|i| {
            WireRequest::zoo(i as u64, names[rng.zipf(names.len())])
                .with("batch", batches[rng.zipf(batches.len())] as u64)
        })
        .collect();
    let mut client = net::Client::connect(&server.local_addr().to_string())?;
    let responses = client.call_many(&requests)?;
    let failed = responses.iter().filter(|r| !r.is_ok()).count();
    let (snapshot, traces) = match client.metrics(n as u64, last)? {
        WireResponse::Metrics {
            snapshot, traces, ..
        } => (snapshot, traces),
        other => dnnabacus::bail!("expected a metrics reply, got {}", other.to_json()),
    };
    drop(client);
    let _ = server.shutdown();
    if json {
        let mut o = Json::obj();
        o.set("requests", n)
            .set("failed", failed)
            .set("accuracy", obs::block_from_snapshot(&snapshot))
            .set("snapshot", snapshot)
            .set("traces", Json::Arr(traces));
        println!("{o}");
    } else {
        print_stats_text(&snapshot, &traces);
    }
    dnnabacus::ensure!(failed == 0, "{failed}/{n} local requests failed");
    Ok(())
}

/// Human rendering of one metrics scrape: the registry tables, the
/// `acc.*` accuracy block (so `--watch` doubles as a drift dashboard),
/// plus one line per recent trace (stage name and microseconds, in
/// span order).
fn print_stats_text(snapshot: &Json, traces: &[Json]) {
    print!("{}", obs::render_snapshot(snapshot));
    print!("{}", obs::render_block(&obs::block_from_snapshot(snapshot)));
    if traces.is_empty() {
        return;
    }
    println!("recent traces ({}):", traces.len());
    for t in traces {
        let id = t.get("trace_id").and_then(Json::as_str).unwrap_or("?");
        let wall = t.get("wall_us").and_then(Json::as_f64).unwrap_or(0.0);
        let spans: Vec<String> = match t.get("spans") {
            Some(Json::Arr(spans)) => spans
                .iter()
                .map(|s| {
                    format!(
                        "{} {:.0}us",
                        s.get("name").and_then(Json::as_str).unwrap_or("?"),
                        s.get("dur_us").and_then(Json::as_f64).unwrap_or(0.0)
                    )
                })
                .collect(),
            _ => Vec::new(),
        };
        println!("  {id}  wall {wall:.0}us  {}", spans.join(" | "));
    }
}

/// `eval`: the unseen-hardware harness. Train the predictor on every
/// device profile except `--holdout`, zero-shot predict on the held-out
/// device, spend `--shots` recorded residuals on the online affine
/// calibrator, and report zero-shot vs calibrated MRE on the disjoint
/// remainder. `--json` prints the BENCH_*-schema report to stdout;
/// `--json PATH` writes it to PATH (the CI bench-smoke artifact).
fn eval(args: &Args) -> dnnabacus::Result<()> {
    let ctx = ctx_from(args);
    let holdout = args.str_or("holdout", "rtx3090");
    let shots = args.usize_or("shots", experiments::calibration::DEFAULT_SHOTS);
    let report = experiments::calibration::holdout_eval(&ctx, &holdout, shots)?;
    match args.get("json") {
        None => println!("{}", report.render()),
        // A bare `--json` parses as the boolean "true".
        Some("true") => println!("{}", report.to_json()),
        Some(path) => {
            std::fs::write(path, report.to_json().to_string())
                .with_context(|| format!("writing {path}"))?;
            println!("{}", report.render());
            println!("wrote {path}");
        }
    }
    Ok(())
}

/// Config overrides for wire requests, from explicitly-passed CLI flags
/// only — absent flags defer to the server's defaults (which lets a
/// spec request inherit the dataset matching its declared geometry).
fn overrides_from(args: &Args) -> dnnabacus::Result<Json> {
    let mut o = Json::obj();
    for key in ["dataset", "optimizer", "framework", "device"] {
        if let Some(v) = args.get(key) {
            o.set(key, v);
        }
    }
    for key in ["batch", "epochs", "seed"] {
        if let Some(v) = args.get(key) {
            let n: u64 = v
                .parse()
                .map_err(|_| dnnabacus::err!("--{key} must be an integer, got '{v}'"))?;
            // These ride as JSON numbers (f64); a value that would
            // round silently is rejected up front.
            dnnabacus::ensure!(
                n <= dnnabacus::net::proto::MAX_SAFE_INT,
                "--{key} {n} exceeds 2^53 and cannot ride the JSON wire format exactly"
            );
            o.set(key, n);
        }
    }
    for (flag, field) in [("data-fraction", "data_fraction"), ("lr", "lr")] {
        if let Some(v) = args.get(flag) {
            let x: f64 = v
                .parse()
                .map_err(|_| dnnabacus::err!("--{flag} must be a number, got '{v}'"))?;
            o.set(field, x);
        }
    }
    Ok(o)
}

/// Load and compile every `*.json` spec under `--specs DIR` (empty when
/// the flag is absent). Specs whose input channels match no dataset are
/// skipped with a note rather than failing the whole load. `quiet`
/// routes the notes to stderr so `--json` stdout stays machine-parsable.
fn load_spec_dir(args: &Args, quiet: bool) -> dnnabacus::Result<Vec<ParsedSpec>> {
    let Some(dir) = args.get("specs") else {
        return Ok(Vec::new());
    };
    let note = |line: String| {
        if quiet {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };
    let mut specs = Vec::new();
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading spec dir {dir}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("json"))
        .collect();
    entries.sort();
    for path in entries {
        let text = std::fs::read_to_string(&path)?;
        let parsed =
            ingest::compile_str(&text).with_context(|| format!("spec {}", path.display()))?;
        if parsed.matching_dataset().is_none() {
            note(format!(
                "skipping {}: no dataset with {}-channel {}x{} samples",
                path.display(),
                parsed.input_channels(),
                parsed.input_hw(),
                parsed.input_hw()
            ));
            continue;
        }
        specs.push(parsed);
    }
    note(format!("loaded {} specs from {dir}", specs.len()));
    Ok(specs)
}

fn nsm_demo(args: &Args) -> dnnabacus::Result<()> {
    let model = args.str_or("model", "resnet18");
    let g = zoo::build(&model, 3, 100)?;
    let nsm = Nsm::build(&g);
    println!(
        "NSM of {model} ({} nodes, {} edges):",
        g.len(),
        g.edge_count()
    );
    println!("{}", nsm.render());
    Ok(())
}
