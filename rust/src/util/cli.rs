//! Tiny CLI argument parser (the offline crate set has no `clap`).
//!
//! Supports `command --flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_and_flags() {
        let a = parse("fig1 --nets vgg16,resnet50 --seed 42 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig1"));
        assert_eq!(a.get("nets"), Some("vgg16,resnet50"));
        assert_eq!(a.u64_or("seed", 0), 42);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn eq_form_and_positional() {
        let a = parse("predict model.json --batch=64 extra");
        assert_eq!(a.command.as_deref(), Some("predict"));
        assert_eq!(a.positional, vec!["model.json", "extra"]);
        assert_eq!(a.usize_or("batch", 0), 64);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("missing", 0.5), 0.5);
        assert!(!a.bool("missing"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("cmd --fast");
        assert!(a.bool("fast"));
    }
}
