//! Content-keyed TTL-LRU cache — the serving layer's answer store.
//!
//! The offline crate set has no `lru`/`moka`; this module is the in-tree
//! replacement the coordinator fronts its batcher with. Keys are 64-bit
//! content digests produced by [`hash64`], a seeded SplitMix64-style
//! byte fold (same mixer constants as [`crate::util::prng`]), so a
//! recurring (model, config) pair always lands on the same entry no
//! matter which client submitted it. Entries expire after a TTL, the
//! least-recently-used live entry is evicted at capacity, and
//! hit/miss/eviction/expiration counters feed the service metrics.

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;
use std::time::{Duration, Instant};

/// Crate-default seed for [`hash64`] content digests.
pub const DIGEST_SEED: u64 = 0x00AB_AC05_D16E_5700;

/// Fold `bytes` into a 64-bit digest under an explicit `seed`, using the
/// SplitMix64 multiplier/finalizer constants from Blackman & Vigna (the
/// same ones [`crate::util::prng::SplitMix64`] steps with). Deterministic
/// across runs and platforms; distinct seeds give de-correlated digests.
pub fn hash64(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = h.rotate_left(31);
    }
    // SplitMix64 finalizer so short inputs still diffuse into all bits.
    h = h.wrapping_add(bytes.len() as u64);
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^ (h >> 31)
}

/// Point-in-time counters for a [`TtlLru`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub expirations: u64,
    pub len: usize,
}

struct Entry<V> {
    value: V,
    expires_at: Instant,
    /// Stamp of this entry's newest recency record in `order`.
    stamp: u64,
}

/// An LRU map with a per-entry time-to-live.
///
/// Recency is tracked with the classic lazy queue: every touch appends a
/// `(key, stamp)` record, and records whose stamp was superseded are
/// skipped on eviction and trimmed opportunistically, giving O(1)
/// amortized operations without a linked list. Not internally
/// synchronized — the service wraps it in a `Mutex`.
pub struct TtlLru<K, V> {
    cap: usize,
    ttl: Duration,
    map: HashMap<K, Entry<V>>,
    /// Recency records, oldest first; stale pairs dropped lazily.
    order: VecDeque<(K, u64)>,
    next_stamp: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    expirations: u64,
}

impl<K: Eq + Hash + Clone, V: Clone> TtlLru<K, V> {
    /// A cache holding at most `capacity.max(1)` entries, each live for
    /// `ttl` after its last insert (lookups refresh recency, not TTL).
    pub fn new(capacity: usize, ttl: Duration) -> Self {
        TtlLru {
            cap: capacity.max(1),
            // Clamp so `Instant + ttl` can never overflow.
            ttl: ttl.min(Duration::from_secs(100 * 365 * 24 * 3600)),
            map: HashMap::new(),
            order: VecDeque::new(),
            next_stamp: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            expirations: 0,
        }
    }

    /// Look up `key`, counting a hit or a miss. An expired entry is
    /// removed and counts as a miss plus an expiration.
    pub fn get(&mut self, key: &K) -> Option<V> {
        self.get_at(key, Instant::now())
    }

    /// [`get`](Self::get) with an explicit clock, for deterministic tests.
    pub fn get_at(&mut self, key: &K, now: Instant) -> Option<V> {
        match self.map.get_mut(key) {
            Some(e) if now < e.expires_at => {
                self.next_stamp += 1;
                e.stamp = self.next_stamp;
                let value = e.value.clone();
                self.order.push_back((key.clone(), self.next_stamp));
                self.hits += 1;
                self.trim_order();
                Some(value)
            }
            Some(_) => {
                self.map.remove(key);
                self.expirations += 1;
                self.misses += 1;
                self.trim_order();
                None
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert or overwrite `key`, evicting least-recently-used entries
    /// while over capacity.
    pub fn insert(&mut self, key: K, value: V) {
        self.insert_at(key, value, Instant::now());
    }

    /// [`insert`](Self::insert) with an explicit clock.
    pub fn insert_at(&mut self, key: K, value: V, now: Instant) {
        self.next_stamp += 1;
        let stamp = self.next_stamp;
        let entry = Entry {
            value,
            expires_at: now + self.ttl,
            stamp,
        };
        self.map.insert(key.clone(), entry);
        self.order.push_back((key, stamp));
        while self.map.len() > self.cap {
            // Oldest record; records superseded by a later touch are
            // stale and skipped, so a live hit here is the true LRU.
            let (k, s) = self.order.pop_front().expect("order tracks map");
            if self.map.get(&k).is_some_and(|e| e.stamp == s) {
                self.map.remove(&k);
                self.evictions += 1;
            }
        }
        self.trim_order();
    }

    /// Drop leading stale recency records, and compact the queue when
    /// stale records dominate, so `order` stays O(live entries).
    fn trim_order(&mut self) {
        loop {
            let stale = match self.order.front() {
                Some((k, s)) => !self.map.get(k).is_some_and(|e| e.stamp == *s),
                None => break,
            };
            if !stale {
                break;
            }
            self.order.pop_front();
        }
        if self.order.len() > 2 * self.map.len() + 8 {
            let map = &self.map;
            self.order.retain(|(k, s)| map.get(k).is_some_and(|e| e.stamp == *s));
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn ttl(&self) -> Duration {
        self.ttl
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            expirations: self.expirations,
            len: self.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn secs(s: u64) -> Duration {
        Duration::from_secs(s)
    }

    #[test]
    fn hash64_deterministic_and_seed_sensitive() {
        assert_eq!(hash64(1, b"vgg16"), hash64(1, b"vgg16"));
        assert_ne!(hash64(1, b"vgg16"), hash64(2, b"vgg16"));
        assert_ne!(hash64(1, b"vgg16"), hash64(1, b"vgg19"));
        assert_ne!(hash64(1, b""), hash64(1, b"\0"));
    }

    #[test]
    fn hash64_spreads_prefix_pairs() {
        // ("ab","c") and ("a","bc") must not collide once callers add
        // separators; here just check raw avalanche on small inputs.
        let mut seen = std::collections::BTreeSet::new();
        for a in 0..=255u8 {
            seen.insert(hash64(7, &[a]));
        }
        assert_eq!(seen.len(), 256);
    }

    #[test]
    fn hit_miss_counters() {
        let mut c: TtlLru<u64, u32> = TtlLru::new(4, secs(60));
        assert_eq!(c.get(&1), None);
        c.insert(1, 10);
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&2), None);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.len), (1, 2, 1));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: TtlLru<&str, u32> = TtlLru::new(2, secs(60));
        c.insert("a", 1);
        c.insert("b", 2);
        assert_eq!(c.get(&"a"), Some(1)); // "b" is now LRU
        c.insert("c", 3);
        assert_eq!(c.get(&"b"), None, "LRU entry evicted");
        assert_eq!(c.get(&"a"), Some(1));
        assert_eq!(c.get(&"c"), Some(3));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn overwrite_does_not_evict() {
        let mut c: TtlLru<u64, u32> = TtlLru::new(2, secs(60));
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&1), Some(11));
        assert_eq!(c.get(&2), Some(20));
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn ttl_expiry_is_a_miss() {
        let mut c: TtlLru<u64, u32> = TtlLru::new(4, secs(10));
        let t0 = Instant::now();
        c.insert_at(1, 10, t0);
        assert_eq!(c.get_at(&1, t0 + secs(5)), Some(10));
        assert_eq!(c.get_at(&1, t0 + secs(11)), None, "expired");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.expirations, s.len), (1, 1, 1, 0));
    }

    #[test]
    fn reinsert_after_expiry_serves_again() {
        let mut c: TtlLru<u64, u32> = TtlLru::new(4, secs(10));
        let t0 = Instant::now();
        c.insert_at(1, 10, t0);
        assert_eq!(c.get_at(&1, t0 + secs(20)), None);
        c.insert_at(1, 12, t0 + secs(20));
        assert_eq!(c.get_at(&1, t0 + secs(25)), Some(12));
    }

    #[test]
    fn recency_queue_stays_bounded_under_hot_key() {
        let mut c: TtlLru<u64, u32> = TtlLru::new(8, secs(60));
        for k in 0..8u64 {
            c.insert(k, k as u32);
        }
        for _ in 0..10_000 {
            assert_eq!(c.get(&3), Some(3));
        }
        assert!(
            c.order.len() <= 2 * c.map.len() + 8,
            "lazy queue leaked: {} records for {} entries",
            c.order.len(),
            c.map.len()
        );
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut c: TtlLru<u64, u32> = TtlLru::new(0, secs(60));
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.len(), 1);
        assert_eq!(c.capacity(), 1);
    }
}
