//! Repo-invariant self-lint: structural rules the compiler cannot
//! enforce, checked as plain unit tests over the source tree so a
//! violation fails `cargo test` with the offending file and line.
//!
//! The invariants:
//!
//! 1. memory-un-safe code is confined to `net/poll.rs` (the one place
//!    that must call `libc`-level `poll(2)` by hand);
//! 2. the server and fleet request paths never panic: no `.unwrap()` /
//!    `.expect(` outside `#[cfg(test)]` modules in `net/` and `fleet/`;
//! 3. the crate stays zero-dependency (`[dependencies]` in Cargo.toml
//!    is empty);
//! 4. every analyzer diagnostic code (`DA0xx`) is documented in
//!    DESIGN.md, so the registry and the docs cannot drift apart;
//! 5. raw atomic counters live only in `obs/` — every other module
//!    counts through the [`crate::obs`] registry, so no metric can
//!    exist outside the unified snapshot (explicit allowlist for the
//!    one non-metric atomic);
//! 6. every metric-name prefix (`svc.`, `net.`, `stage.`, `fleet.`,
//!    `acc.`) has a row in DESIGN.md §4f's naming table, so new
//!    instrument families cannot ship undocumented.

#[cfg(test)]
mod tests {
    use std::fs;
    use std::path::{Path, PathBuf};

    fn root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
    }

    fn read(path: &Path) -> String {
        match fs::read_to_string(path) {
            Ok(text) => text,
            Err(e) => panic!("selflint cannot read {}: {e}", path.display()),
        }
    }

    /// Every `.rs` file under `dir`, recursively, in sorted order.
    fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
        let entries = match fs::read_dir(dir) {
            Ok(entries) => entries,
            Err(e) => panic!("selflint cannot list {}: {e}", dir.display()),
        };
        let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
        paths.sort();
        for path in paths {
            if path.is_dir() {
                rust_files(&path, out);
            } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
                out.push(path);
            }
        }
    }

    /// `(1-based line, text)` pairs up to (excluding) the file's first
    /// `#[cfg(test)]` — the non-test portion of a source file.
    fn non_test_lines(text: &str) -> Vec<(usize, &str)> {
        text.lines()
            .enumerate()
            .take_while(|(_, line)| !line.contains("#[cfg(test)]"))
            .map(|(i, line)| (i.saturating_add(1), line))
            .collect()
    }

    #[test]
    fn memory_un_safe_code_is_confined_to_the_poller() {
        // Needle built by concatenation (and the fn name underscored) so
        // this file never matches itself.
        let needle: String = ["un", "safe"].concat();
        let src = root().join("rust/src");
        let mut files = Vec::new();
        rust_files(&src, &mut files);
        assert!(files.len() > 30, "source walk looks broken: {files:?}");
        let mut violations = Vec::new();
        for path in files {
            if path.ends_with("net/poll.rs") {
                continue;
            }
            let text = read(&path);
            for (line, content) in text.lines().enumerate() {
                if content.contains(&needle) {
                    violations.push(format!(
                        "{}:{}: {}",
                        path.display(),
                        line.saturating_add(1),
                        content.trim()
                    ));
                }
            }
        }
        assert!(
            violations.is_empty(),
            "{needle} outside net/poll.rs:\n{}",
            violations.join("\n")
        );
    }

    #[test]
    fn request_paths_never_panic() {
        let root = root();
        let mut files = Vec::new();
        rust_files(&root.join("rust/src/net"), &mut files);
        rust_files(&root.join("rust/src/fleet"), &mut files);
        assert!(files.len() >= 12, "source walk looks broken: {files:?}");
        let mut violations = Vec::new();
        for path in files {
            let text = read(&path);
            for (line, content) in non_test_lines(&text) {
                if content.contains(".unwrap()") || content.contains(".expect(") {
                    violations.push(format!("{}:{line}: {}", path.display(), content.trim()));
                }
            }
        }
        assert!(
            violations.is_empty(),
            "panicking calls on server/fleet request paths:\n{}",
            violations.join("\n")
        );
    }

    #[test]
    fn raw_counters_live_only_in_the_obs_registry() {
        // Needle built by concatenation so this file never matches
        // itself. Files under `obs/` are the registry implementation;
        // the allowlist names the one non-metric atomic (the batcher's
        // internal steal accounting, surfaced as a gauge by the
        // service).
        let needle: String = ["Atomic", "U64"].concat();
        let allowed = ["coordinator/batcher.rs"];
        let src = root().join("rust/src");
        let mut files = Vec::new();
        rust_files(&src, &mut files);
        assert!(files.len() > 30, "source walk looks broken: {files:?}");
        let mut violations = Vec::new();
        for path in files {
            let rel = path.to_string_lossy().replace('\\', "/");
            if rel.contains("/obs/") || allowed.iter().any(|a| rel.ends_with(a)) {
                continue;
            }
            let text = read(&path);
            for (line, content) in non_test_lines(&text) {
                if content.contains(&needle) {
                    violations.push(format!("{}:{line}: {}", path.display(), content.trim()));
                }
            }
        }
        assert!(
            violations.is_empty(),
            "raw {needle} counters outside obs/ (register a Counter/Gauge instead):\n{}",
            violations.join("\n")
        );
    }

    #[test]
    fn crate_stays_zero_dependency() {
        let manifest = read(&root().join("Cargo.toml"));
        let mut in_deps = false;
        for (i, line) in manifest.lines().enumerate() {
            let t = line.trim();
            if t.starts_with('[') {
                in_deps = t == "[dependencies]";
                continue;
            }
            if in_deps && !t.is_empty() && !t.starts_with('#') {
                panic!(
                    "Cargo.toml:{}: dependency in a zero-dep crate: {t}",
                    i.saturating_add(1)
                );
            }
        }
    }

    #[test]
    fn every_metric_prefix_has_a_naming_table_row() {
        let design = read(&root().join("DESIGN.md"));
        let missing: Vec<&str> = ["svc.", "net.", "stage.", "fleet.", "acc."]
            .into_iter()
            .filter(|prefix| !design.contains(&format!("| `{prefix}` |")))
            .collect();
        assert!(
            missing.is_empty(),
            "DESIGN.md §4f naming table is missing prefix rows {missing:?}"
        );
    }

    #[test]
    fn every_diagnostic_code_is_documented() {
        let design = read(&root().join("DESIGN.md"));
        let missing: Vec<&str> = crate::analyze::Code::ALL
            .iter()
            .map(|c| c.as_str())
            .filter(|code| !design.contains(*code))
            .collect();
        assert!(
            missing.is_empty(),
            "DESIGN.md is missing analyzer codes {missing:?} — document them in §4e"
        );
    }
}
