//! A small fixed-size thread pool with a `scope`-style parallel map.
//!
//! The offline crate set has neither `tokio` nor `rayon`; the coordinator
//! service and the dataset sweeps use this pool. Work items are boxed
//! closures pushed over an MPSC channel guarded by a mutex (fan-out) and
//! results collected over a return channel (fan-in).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("dnnabacus-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed -> shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            queued,
        }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Parallel map: applies `f` to every item, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker panicked");
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit after draining
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100usize).collect(), |x| x * x);
        assert_eq!(out, (0..100usize).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn map_on_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_is_serial_but_complete() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![3usize, 1, 2], |x| x + 1);
        assert_eq!(out, vec![4, 2, 3]);
    }
}
