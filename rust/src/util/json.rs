//! Minimal JSON value model, parser and writer.
//!
//! The offline crate set has no `serde`/`serde_json`; datasets, trained
//! models and experiment reports are persisted through this module. It
//! supports the full JSON grammar minus exotic number forms, with
//! round-trip-exact `f64` printing (via shortest-repr fallback to `{:e}`;
//! non-finite numbers serialize as `null` — see `fmt_f64` for the
//! policy). Parse errors report `line L column C (byte B)` — the ingest
//! pipeline makes them user-facing diagnostics for hand-authored model
//! specs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `get(key)` then `as_f64`, with a descriptive error.
    pub fn num(&self, key: &str) -> crate::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| crate::err!("missing numeric field '{key}'"))
    }

    pub fn str(&self, key: &str) -> crate::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| crate::err!("missing string field '{key}'"))
    }

    pub fn arr(&self, key: &str) -> crate::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| crate::err!("missing array field '{key}'"))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            crate::bail!("trailing characters at {}", p.at());
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}
impl From<&[f64]> for Json {
    fn from(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => write!(f, "{}", fmt_f64(*x)),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Format an f64 so it parses back to the identical bits (for finite x).
///
/// JSON has no NaN/Infinity tokens, and a serializer that emits them
/// produces documents our own [`Json::parse`] (and every other parser)
/// rejects — unacceptable for wire-protocol responses. Policy, pinned
/// by tests: **non-finite numbers serialize as `null`** and parse back
/// as [`Json::Null`]. Clamping to huge finite magnitudes (the previous
/// behavior) silently fabricated values; an explicit `null` is honest
/// about "no representable number here".
fn fmt_f64(x: f64) -> String {
    if !x.is_finite() {
        return "null".into();
    }
    if x == x.trunc() && x.abs() < 1e15 {
        return format!("{}", x as i64);
    }
    let short = format!("{x}");
    if short.parse::<f64>() == Ok(x) {
        short
    } else {
        format!("{x:e}")
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    /// `line L column C (byte B)` for an arbitrary byte offset. Parser
    /// errors are user-facing (the ingest pipeline reads user-authored
    /// model specs), so they point into the source text instead of
    /// reporting a bare byte offset. Columns count bytes from the last
    /// newline, which matches editors for ASCII documents.
    fn at_byte(&self, pos: usize) -> String {
        let upto = &self.bytes[..pos.min(self.bytes.len())];
        let line = 1 + upto.iter().filter(|&&b| b == b'\n').count();
        let col = 1 + upto.iter().rev().take_while(|&&b| b != b'\n').count();
        format!("line {line} column {col} (byte {pos})")
    }

    /// [`at_byte`](Self::at_byte) for the current position.
    fn at(&self) -> String {
        self.at_byte(self.pos)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            crate::bail!(
                "expected '{}' at {}, found {:?}",
                b as char,
                self.at(),
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => crate::bail!("unexpected {:?} at {}", other.map(|c| c as char), self.at()),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> crate::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            crate::bail!("invalid literal at {}", self.at())
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self
                .peek()
                .ok_or_else(|| crate::err!("unterminated string at {}", self.at()))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self
                        .peek()
                        .ok_or_else(|| crate::err!("unterminated escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos..self.pos + 4)
                                    .ok_or_else(|| crate::err!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => crate::bail!("bad escape \\{} at {}", e as char, self.at()),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let x: f64 = s
            .parse()
            .map_err(|_| crate::err!("invalid number '{s}' at {}", self.at_byte(start)))?;
        Ok(Json::Num(x))
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => crate::bail!("expected ',' or ']' at {}", self.at()),
            }
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => crate::bail!("expected ',' or '}}' at {}", self.at()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for s in ["null", "true", "false", "0", "-1", "3.25"] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_floats_exact() {
        for x in [0.1, 1.0 / 3.0, 1e-12, 123456.789, f64::MAX, -1.5e-300] {
            let v = Json::Num(x);
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(back.as_f64().unwrap(), x);
        }
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // Regression: NaN/±inf must never render an unparseable token —
        // wire-protocol responses go through this writer. Pinned policy:
        // they serialize as `null` and parse back as `Json::Null`.
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(x).to_string();
            assert_eq!(s, "null");
            assert_eq!(Json::parse(&s).unwrap(), Json::Null);
        }
        // A poisoned metric inside a document must not take the whole
        // document down with it.
        let mut o = Json::obj();
        o.set("bad", f64::NAN).set("good", 1.5);
        let back = Json::parse(&o.to_string()).unwrap();
        assert_eq!(back.get("bad"), Some(&Json::Null));
        assert_eq!(back.num("good").unwrap(), 1.5);
        // Arrays too: every element stays parseable.
        let arr = Json::from(vec![1.0, f64::INFINITY, 3.0]);
        assert_eq!(arr.to_string(), "[1,null,3]");
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a":[1,2,{"b":"x\ny","c":null}],"d":true}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let v = Json::parse(r#""Aλ\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aλ\t");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn errors_carry_line_and_column() {
        // The bad token `x` sits on line 3, column 8.
        let text = "{\n  \"a\": 1,\n  \"b\": x\n}";
        let e = Json::parse(text).unwrap_err().to_string();
        assert!(e.contains("line 3 column 8"), "{e}");

        let e = Json::parse("[1, 2,\n 3!]").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");

        // Errors on line 1 (no newline yet) still report positions.
        let e = Json::parse("[1 2]").unwrap_err().to_string();
        assert!(e.contains("line 1 column 4"), "{e}");
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("name", "vgg16").set("mre", 0.009).set("n", 29usize);
        let s = o.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.str("name").unwrap(), "vgg16");
        assert_eq!(back.num("n").unwrap(), 29.0);
    }
}
