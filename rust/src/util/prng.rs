//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry our own generators:
//! SplitMix64 for seeding and xoshiro256++ for the main stream. Both are
//! the reference algorithms from Blackman & Vigna, chosen for speed and
//! reproducibility: every simulator run, dataset sweep and GA search in
//! this crate is keyed by an explicit `u64` seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the crate-wide PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give de-correlated
    /// streams via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-worker / per-run seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0` — the message
    /// names this method so a load generator handing an empty mix to
    /// [`choose`](Self::choose)/[`zipf`](Self::zipf) fails loudly at
    /// the culprit instead of with a bare index-out-of-bounds.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0): n must be positive (empty mix?)");
        // Lemire's method without rejection is fine for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Zipf-ish rank sample in `[0, n)`: the probability of rank `r`
    /// decays like `1/(r+1)` (inverse CDF of a log density — exact
    /// weight `ln(1 + 1/(r+1))`, O(1) per draw). Skewed request mixes
    /// for the serving benchmarks come from here. Panics if `n == 0`.
    #[inline]
    pub fn zipf(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::zipf(0): n must be positive (empty mix?)");
        let r = ((n as f64 + 1.0).powf(self.f64()) - 1.0).floor() as usize;
        r.min(n - 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Pick a uniformly random element of a slice. Panics with a named
    /// message on an empty slice — previously this surfaced as an
    /// opaque `Rng::below(0)` assert deep in the sampler. (Audit note:
    /// every in-tree load-generator mix is either a non-empty constant
    /// array or guarded by an `is_empty` check before sampling.)
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "Rng::choose on an empty slice");
        &xs[self.below(xs.len())]
    }

    /// In-place Fisher–Yates shuffle. Empty and single-element slices
    /// are no-ops (the loop body never runs, so no `below(0)` panic).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random sample of `k` indices out of `0..n` without replacement.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn zipf_bounds_and_skew() {
        let mut r = Rng::new(12);
        let n = 20;
        let mut counts = vec![0usize; n];
        for _ in 0..20_000 {
            let k = r.zipf(n);
            assert!(k < n);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[n - 1] * 4, "head {} tail {}", counts[0], counts[n - 1]);
        assert!(counts[0] > counts[4], "rank 0 beats rank 4");
        assert!(counts.iter().all(|&c| c > 0), "full support");
    }

    #[test]
    #[should_panic(expected = "Rng::choose on an empty slice")]
    fn choose_on_empty_slice_names_the_caller() {
        // Regression: this used to die inside `below` with an assert
        // that never mentioned which sampler was handed an empty mix.
        let mut r = Rng::new(1);
        let empty: [u8; 0] = [];
        let _ = r.choose(&empty);
    }

    #[test]
    #[should_panic(expected = "Rng::zipf(0)")]
    fn zipf_zero_names_the_caller() {
        let mut r = Rng::new(1);
        let _ = r.zipf(0);
    }

    #[test]
    fn shuffle_empty_and_singleton_are_noops() {
        let mut r = Rng::new(2);
        let mut empty: Vec<u8> = vec![];
        r.shuffle(&mut empty); // must not panic
        assert!(empty.is_empty());
        let mut one = vec![42];
        r.shuffle(&mut one);
        assert_eq!(one, vec![42]);
    }

    #[test]
    fn sample_indices_unique() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
    }
}
