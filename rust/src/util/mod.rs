//! Support substrates built in-tree because the sandbox is offline:
//! PRNG (no `rand`), minimal JSON (no `serde`), stats, CLI parsing
//! (no `clap`), a thread pool (no `tokio`/`rayon`), a small
//! property-testing driver (no `proptest`), a content-keyed TTL-LRU
//! cache (no `lru`/`moka`), and the crate error type (no
//! `anyhow`/`thiserror`).

pub mod cache;
pub mod cli;
pub mod error;
pub mod json;
pub mod prng;
pub mod prop;
mod selflint;
pub mod stats;
pub mod table;
pub mod threadpool;
