//! Support substrates built in-tree because the sandbox is offline:
//! PRNG (no `rand`), minimal JSON (no `serde`), stats, CLI parsing
//! (no `clap`), a thread pool (no `tokio`/`rayon`), and a small
//! property-testing driver (no `proptest`).

pub mod prng;
pub mod json;
pub mod stats;
pub mod cli;
pub mod threadpool;
pub mod prop;
pub mod table;
