//! Statistics used throughout the evaluation: mean relative error (the
//! paper's headline metric), RMSE, quantiles, Spearman correlation, and
//! simple summary helpers for the bench harness.

/// Mean relative error: `mean(|pred - true| / |true|)`, the paper's metric.
/// Targets with `|true| < eps` are skipped to avoid division blow-up.
pub fn mre(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut sum = 0.0;
    let mut n = 0usize;
    for (&p, &t) in pred.iter().zip(truth) {
        if t.abs() > 1e-12 {
            sum += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let ss: f64 = pred
        .iter()
        .zip(truth)
        .map(|(&p, &t)| (p - t) * (p - t))
        .sum();
    (ss / pred.len() as f64).sqrt()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// q-quantile (0 <= q <= 1) with linear interpolation on a copy.
///
/// Policy for pathological input: non-finite samples (NaN, ±inf) are
/// dropped before ranking — a single poisoned latency must not panic
/// the comparator or smear into every percentile of a service report —
/// and an empty (or all-non-finite) input yields 0.0. The sort uses
/// `f64::total_cmp`, so the comparator itself is total even if the
/// filter policy changes.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Several quantiles of the same sample in one pass: filters and sorts
/// once, then interpolates each requested `q` — the repeated-sort-free
/// form of calling [`quantile`] per percentile on a hot report path.
///
/// Same pathological-input policy as [`quantile`]: non-finite samples
/// are dropped, the comparator is `f64::total_cmp`, and an empty (or
/// all-non-finite) input yields 0.0 for every requested quantile, so
/// `quantiles(xs, &[q]) == vec![quantile(xs, q)]` for all inputs.
pub fn quantiles(xs: &[f64], qs: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return vec![0.0; qs.len()];
    }
    v.sort_by(f64::total_cmp);
    qs.iter()
        .map(|q| {
            let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            if lo == hi {
                v[lo]
            } else {
                v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
            }
        })
        .collect()
}

/// Smallest element, ignoring NaNs. An empty (or all-NaN) slice yields
/// 0.0 — a defined sentinel for reports, not `+inf` leaking into JSON.
pub fn min(xs: &[f64]) -> f64 {
    let mut it = xs.iter().copied().filter(|x| !x.is_nan());
    match it.next() {
        None => 0.0,
        Some(first) => it.fold(first, f64::min),
    }
}

/// Largest element, ignoring NaNs. An empty (or all-NaN) slice yields
/// 0.0 — a defined sentinel for reports, not `-inf` leaking into JSON.
pub fn max(xs: &[f64]) -> f64 {
    let mut it = xs.iter().copied().filter(|x| !x.is_nan());
    match it.next() {
        None => 0.0,
        Some(first) => it.fold(first, f64::max),
    }
}

/// Coefficient of determination R².
pub fn r2(pred: &[f64], truth: &[f64]) -> f64 {
    let m = mean(truth);
    let ss_tot: f64 = truth.iter().map(|t| (t - m) * (t - m)).sum();
    let ss_res: f64 = pred
        .iter()
        .zip(truth)
        .map(|(&p, &t)| (p - t) * (p - t))
        .sum();
    if ss_tot == 0.0 {
        0.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    // total_cmp keeps the comparator total (NaNs rank after +inf)
    // instead of panicking — same policy as `quantile`.
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // Average ranks over ties.
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation, used to check that predictions preserve
/// job ordering (what the scheduler actually needs).
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.len() < 2 {
        return 1.0;
    }
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let ma = mean(a);
    let mb = mean(b);
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        num += (x - ma) * (y - mb);
        da += (x - ma) * (x - ma);
        db += (y - mb) * (y - mb);
    }
    if da == 0.0 || db == 0.0 {
        0.0
    } else {
        num / (da * db).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mre_basic() {
        assert!((mre(&[110.0], &[100.0]) - 0.1).abs() < 1e-12);
        assert!((mre(&[90.0, 110.0], &[100.0, 100.0]) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn mre_skips_zero_targets() {
        assert_eq!(mre(&[5.0, 100.0], &[0.0, 100.0]), 0.0);
    }

    #[test]
    fn rmse_basic() {
        // (1² + 3²)/2 = 5.
        assert!((rmse(&[1.0, 3.0], &[0.0, 0.0]) - 5.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_drops_non_finite_instead_of_panicking() {
        // Regression: `partial_cmp(..).unwrap()` used to panic on NaN.
        let xs = [3.0, f64::NAN, 1.0, f64::INFINITY, 2.0, f64::NEG_INFINITY, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
        // Degenerate inputs have a defined result.
        assert_eq!(quantile(&[], 0.5), 0.0);
        assert_eq!(quantile(&[f64::NAN, f64::NAN], 0.5), 0.0);
    }

    #[test]
    fn quantiles_matches_quantile_per_element() {
        let xs = [3.0, f64::NAN, 1.0, f64::INFINITY, 2.0, f64::NEG_INFINITY, 4.0];
        let qs = [0.0, 0.25, 0.5, 0.95, 1.0];
        let batch = quantiles(&xs, &qs);
        assert_eq!(batch.len(), qs.len());
        for (&q, &got) in qs.iter().zip(&batch) {
            assert!(
                (got - quantile(&xs, q)).abs() < 1e-12,
                "q={q}: batch {got} != scalar {}",
                quantile(&xs, q)
            );
        }
    }

    #[test]
    fn quantiles_defined_on_degenerate_input() {
        // Same empty/NaN policy as `quantile`: zeros, never a panic.
        assert_eq!(quantiles(&[], &[0.5, 0.99]), vec![0.0, 0.0]);
        assert_eq!(quantiles(&[f64::NAN, f64::NAN], &[0.5]), vec![0.0]);
        assert_eq!(quantiles(&[1.0, 2.0], &[]), Vec::<f64>::new());
        // Out-of-range q clamps like the scalar form.
        assert_eq!(quantiles(&[1.0, 2.0], &[-1.0, 2.0]), vec![1.0, 2.0]);
    }

    #[test]
    fn min_max_defined_on_empty_and_nan() {
        // Regression: empty slices used to return ±inf into reports.
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert_eq!(min(&[f64::NAN]), 0.0);
        assert_eq!(max(&[f64::NAN]), 0.0);
        assert_eq!(min(&[2.0, f64::NAN, 1.0]), 1.0);
        assert_eq!(max(&[2.0, f64::NAN, 1.0]), 2.0);
        assert_eq!(min(&[3.5]), 3.5);
        assert_eq!(max(&[3.5]), 3.5);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 40.0, 80.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_reversed_is_minus_one() {
        let a = [1.0, 2.0, 3.0];
        let b = [9.0, 5.0, 1.0];
        assert!((spearman(&a, &b) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_tolerates_nan_without_panicking() {
        // Regression: the rank sort used the same panicking
        // `partial_cmp(..).unwrap()` comparator `quantile` was cured
        // of. NaN input may yield a NaN correlation, but never a panic.
        let r = spearman(&[1.0, f64::NAN, 2.0], &[3.0, 1.0, 2.0]);
        assert!(!r.is_infinite());
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0, 1.0, 2.0];
        let b = [3.0, 3.0, 5.0];
        assert!(spearman(&a, &b) > 0.99);
    }

    #[test]
    fn r2_perfect() {
        let t = [1.0, 2.0, 3.0];
        assert!((r2(&t, &t) - 1.0).abs() < 1e-12);
    }
}
