//! Plain-text table rendering for experiment reports and benches — the
//! figures in the paper are reproduced as aligned text tables / CSV rows
//! so diffs against EXPERIMENTS.md stay readable.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let line = |cells: &[String], width: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<w$}", c, w = width[i]));
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &width));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for plotting outside).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|c| esc(c))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2}GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.1}MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.1}KiB", b / KIB)
    } else {
        format!("{b:.0}B")
    }
}

/// Format a ratio as a percentage.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["net", "mre"]);
        t.row(vec!["vgg16".into(), "1.2%".into()]);
        t.row(vec!["shufflenet-v2".into(), "0.9%".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("vgg16"));
        // Columns aligned: both rows have the '%' column starting at the
        // same offset.
        let lines: Vec<&str> = r.lines().collect();
        let c1 = lines[3].find("1.2%").unwrap();
        let c2 = lines[4].find("0.9%").unwrap();
        assert_eq!(c1, c2);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn human_formats() {
        assert_eq!(fmt_bytes(1024), "1.0KiB");
        assert_eq!(fmt_bytes(3 << 30), "3.00GiB");
        assert_eq!(fmt_secs(0.5), "500.00ms");
        assert_eq!(fmt_pct(0.0283), "2.83%");
    }
}
