//! A small property-testing driver (the offline crate set has no
//! `proptest`).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded RNGs derived
//! from a fixed master seed (override with `DNNABACUS_PROP_SEED` to
//! replay). On failure it panics with the failing case seed so the exact
//! input can be reproduced with `check_one`.

use crate::util::prng::Rng;

/// Default number of cases per property (kept modest: the suite has
/// hundreds of properties and runs on one core).
pub const DEFAULT_CASES: usize = 64;

fn master_seed() -> u64 {
    std::env::var("DNNABACUS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD11A_BAC5u64)
}

/// Run `f` on `cases` independent seeded RNGs; panic with replay info on
/// the first failure (any panic inside `f` counts as a failure).
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: usize, f: F) {
    let mut root = Rng::new(master_seed() ^ fxhash(name));
    for case in 0..cases {
        let seed = root.next_u64();
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_one<F: FnMut(&mut Rng)>(seed: u64, mut f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

/// FNV-1a over the property name so each property has its own stream.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 32, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always-fails", 4, |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = None;
        check_one(42, |rng| {
            let v = rng.next_u64();
            if let Some(prev) = first {
                assert_eq!(prev, v);
            }
            first = Some(v);
        });
        check_one(42, |rng| {
            assert_eq!(first.unwrap(), rng.next_u64());
        });
    }
}
