//! Zero-dependency crate error type.
//!
//! The offline crate set has no `anyhow`/`thiserror`; this module is the
//! in-tree replacement. [`DnnError`] carries a chain of human-readable
//! messages from the outermost context down to the root cause:
//!
//! * `{err}` prints the outermost message;
//! * `{err:#}` prints the whole chain joined with `": "` (what the CLI
//!   prints on failure);
//! * any `std::error::Error` converts via `From`, so `?` works on
//!   `std::io`, parse, channel-recv and simulator errors alike;
//! * [`Context`] adds `.context(...)` / `.with_context(...)` on both
//!   `Result` and `Option`, mirroring the `anyhow` idiom the call sites
//!   were written against.
//!
//! The companion macros live at the crate root (`crate::err!`,
//! `crate::bail!`, `crate::ensure!`) because `#[macro_export]` hoists
//! them there.

use std::fmt;

/// Crate-wide error: an outermost-first chain of messages.
///
/// Deliberately *not* an implementation of `std::error::Error`: that
/// keeps the blanket `From<E: std::error::Error>` conversion coherent
/// (the same trick `anyhow::Error` uses).
#[derive(Clone)]
pub struct DnnError {
    /// Messages from outermost context (index 0) to root cause (last).
    chain: Vec<String>,
}

impl DnnError {
    /// A fresh error with a single message.
    pub fn msg(message: impl Into<String>) -> DnnError {
        DnnError {
            chain: vec![message.into()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, message: impl Into<String>) -> DnnError {
        self.chain.insert(0, message.into());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for DnnError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, as the CLI error path prints it.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for DnnError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "DnnError({})", self.chain.join(": "))
    }
}

/// Any standard error converts into a single-message [`DnnError`], which
/// is what makes `?` work across `std::io::Error`, `std::fmt::Error`,
/// parse errors, `mpsc::RecvError`, [`crate::sim::OomError`], ….
impl<E: std::error::Error> From<E> for DnnError {
    fn from(e: E) -> DnnError {
        // Preserve the source chain as message segments.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        DnnError { chain }
    }
}

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error (eagerly evaluated).
    fn context(self, message: impl Into<String>) -> Result<T>;

    /// Attach a lazily-built context message.
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: Into<DnnError>> Context<T> for std::result::Result<T, E> {
    fn context(self, message: impl Into<String>) -> Result<T> {
        self.map_err(|e| e.into().context(message))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, message: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| DnnError::msg(message))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.ok_or_else(|| DnnError::msg(f()))
    }
}

/// Crate-wide result alias (re-exported at the crate root).
pub type Result<T> = std::result::Result<T, DnnError>;

/// Build a [`DnnError`] from a format string: `err!("bad batch {b}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::DnnError::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`DnnError`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with a formatted [`DnnError`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_outermost_alternate_full_chain() {
        let e = DnnError::msg("root").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn debug_shows_chain() {
        let e = DnnError::msg("inner").context("ctx");
        assert_eq!(format!("{e:?}"), "DnnError(ctx: inner)");
    }

    #[test]
    fn from_io_error() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing.json");
        let e: DnnError = io.into();
        assert!(format!("{e}").contains("missing.json"));
    }

    #[test]
    fn from_fmt_error() {
        let e: DnnError = std::fmt::Error.into();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn question_mark_converts_io() {
        fn read() -> Result<String> {
            let text = std::fs::read_to_string("/definitely/not/a/path/xyz")?;
            Ok(text)
        }
        assert!(read().is_err());
    }

    #[test]
    fn question_mark_converts_parse() {
        fn parse() -> Result<f64> {
            Ok("not-a-number".parse::<f64>()?)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn result_context_chains() {
        fn inner() -> Result<()> {
            Err(err!("root cause"))
        }
        let out: Result<()> = inner().context("loading dataset");
        let e = out.unwrap_err();
        assert_eq!(format!("{e}"), "loading dataset");
        assert_eq!(format!("{e:#}"), "loading dataset: root cause");
    }

    #[test]
    fn result_with_context_lazy() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "step 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field 'batch'").unwrap_err();
        assert_eq!(format!("{e}"), "missing field 'batch'");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
    }

    #[test]
    fn from_preserves_source_chain() {
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "outer layer")
            }
        }
        impl std::error::Error for Outer {
            fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
                Some(&self.0)
            }
        }
        let io = std::io::Error::other("disk on fire");
        let e: DnnError = Outer(io).into();
        assert_eq!(format!("{e:#}"), "outer layer: disk on fire");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<DnnError>();
    }
}
